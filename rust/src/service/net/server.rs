//! The readiness-loop server: event-loop threads + a dispatch pool.
//!
//! Each event-loop thread owns a [`Poller`], a `try_clone` of the
//! listener (the kernel's level-triggered accept readiness spreads
//! connections across loops), a slab of [`Conn`] state machines, and a
//! [`TimerWheel`] enforcing idle deadlines. Parsed requests are pushed
//! onto a shared dispatch [`ThreadPool`] where the *blocking* part —
//! the engine submit + wait — runs; the serialized response comes back
//! through a per-loop completion queue and a pipe [`Waker`]. Event
//! loops therefore never block on the engine: a loop keeps thousands
//! of connections moving while the dispatch pool's depth (not the
//! connection count) bounds how much work sits in the engine queue.
//!
//! Tokens are `slot | epoch << 32`: the epoch increments every time a
//! slab slot is reused, so completions and timer entries that outlive
//! their connection are recognized as stale and dropped instead of
//! touching an unrelated connection.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::conn::{Conn, ParseStep, PIPELINE_MAX};
use super::{sys, waker_pair, Backend, Event, Interest, Poller, TimerWheel, Waker, WakeReader};
use crate::obs::trace::unix_us;
use crate::obs::{NetStats, TraceRecorder};
use crate::service::api::ServiceError;
use crate::service::http::{self, ServeOptions};
use crate::service::registry::ModelRegistry;
use crate::util::threadpool::{default_threads, ThreadPool};

/// Poller token for the listener registration.
const TOKEN_LISTENER: u64 = u64::MAX;
/// Poller token for the waker pipe.
const TOKEN_WAKER: u64 = u64::MAX - 1;
/// Max accepts drained per listener readiness event (fairness).
const ACCEPT_BURST: usize = 128;
/// Max socket reads per connection per readiness event (fairness);
/// level-triggered readiness re-fires for whatever is left.
const READ_BURST: usize = 8;
/// Timer wheel size; deadlines beyond `slots × tick` re-insert on scan.
const WHEEL_SLOTS: usize = 512;

fn token(slot: usize, epoch: u32) -> u64 {
    slot as u64 | ((epoch as u64) << 32)
}

fn untoken(t: u64) -> (usize, u32) {
    ((t & 0xFFFF_FFFF) as usize, (t >> 32) as u32)
}

/// A finished dispatch job: the serialized response for one request.
struct Completion {
    slot: usize,
    epoch: u32,
    bytes: Vec<u8>,
    keep_alive: bool,
    /// Trace to annotate with the response's `net_flush` interval
    /// (recorder + trace id), for traced infer requests.
    trace: Option<(Arc<TraceRecorder>, u64)>,
}

/// The cross-thread half of one event loop: where dispatch workers
/// park finished responses, plus the waker that un-parks the loop.
struct LoopShared {
    completions: Mutex<Vec<Completion>>,
    waker: Waker,
}

/// Slot-reuse-safe connection store.
#[derive(Default)]
struct Slab {
    entries: Vec<Option<Conn>>,
    epochs: Vec<u32>,
    free: Vec<usize>,
}

impl Slab {
    fn insert(&mut self, conn: Conn) -> (usize, u32) {
        if let Some(slot) = self.free.pop() {
            self.entries[slot] = Some(conn);
            (slot, self.epochs[slot])
        } else {
            self.entries.push(Some(conn));
            self.epochs.push(0);
            (self.entries.len() - 1, 0)
        }
    }

    fn remove(&mut self, slot: usize) -> Option<Conn> {
        let conn = self.entries.get_mut(slot)?.take()?;
        self.epochs[slot] = self.epochs[slot].wrapping_add(1);
        self.free.push(slot);
        Some(conn)
    }

    fn epoch(&self, slot: usize) -> u32 {
        self.epochs[slot]
    }

    /// Occupant of `slot` regardless of epoch (single-loop-local use).
    fn slot_mut(&mut self, slot: usize) -> Option<&mut Conn> {
        self.entries.get_mut(slot)?.as_mut()
    }

    /// Epoch-checked lookup for tokens that crossed threads or time.
    fn checked_mut(&mut self, slot: usize, epoch: u32) -> Option<&mut Conn> {
        if self.epochs.get(slot) != Some(&epoch) {
            return None;
        }
        self.slot_mut(slot)
    }
}

/// One event-loop thread's whole world.
struct EventLoop {
    poller: Poller,
    listener: TcpListener,
    conns: Slab,
    wheel: TimerWheel,
    shared: Arc<LoopShared>,
    wake_rx: WakeReader,
    registry: Arc<ModelRegistry>,
    dispatch: Arc<ThreadPool>,
    stop: Arc<AtomicBool>,
    /// Net-layer lifecycle counters, shared with the registry's
    /// `/metrics` exposition; `net.live` doubles as the enforcement
    /// counter for the `max_conns` cap across *all* loops.
    net: Arc<NetStats>,
    opts: ServeOptions,
    /// Pre-serialized 503 for over-cap connections.
    overload: Arc<Vec<u8>>,
}

impl EventLoop {
    fn run(mut self) {
        let tick = self.wheel.tick();
        let mut events: Vec<Event> = Vec::with_capacity(256);
        while !self.stop.load(Ordering::Acquire) {
            events.clear();
            if self.poller.wait(&mut events, tick).is_err() {
                // Transient wait failure: don't spin hot.
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
            if self.stop.load(Ordering::Acquire) {
                break;
            }
            for &ev in &events {
                match ev.token {
                    TOKEN_WAKER => self.wake_rx.drain(),
                    TOKEN_LISTENER => {
                        if ev.readable {
                            self.accept_burst();
                        }
                    }
                    _ => self.on_conn_event(ev),
                }
            }
            self.apply_completions();
            self.fire_timers(Instant::now());
        }
    }

    // ---- accept ---------------------------------------------------------

    fn accept_burst(&mut self) {
        for _ in 0..ACCEPT_BURST {
            match self.listener.accept() {
                Ok((stream, _)) => self.admit(stream),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn admit(&mut self, stream: TcpStream) {
        // Same cap semantics as the thread-per-connection server: count
        // first, refuse with a short best-effort 503 when over. The
        // refusal *write* runs on the dispatch pool: the just-accepted
        // socket is still blocking, so a hostile peer that never reads
        // could otherwise stall this event loop for the full write
        // timeout while live connections sit unserved.
        let n = self.net.live.fetch_add(1, Ordering::AcqRel) + 1;
        if n > self.opts.max_conns {
            self.net.live.fetch_sub(1, Ordering::AcqRel);
            self.net.refused.fetch_add(1, Ordering::Relaxed);
            let overload = Arc::clone(&self.overload);
            self.dispatch
                .submit(move || refuse_overloaded(stream, &overload));
            return;
        }
        // Accepted sockets do not inherit the listener's non-blocking
        // mode on Linux; set it explicitly.
        if stream.set_nonblocking(true).is_err() {
            self.net.live.fetch_sub(1, Ordering::AcqRel);
            return;
        }
        let _ = stream.set_nodelay(true);
        if let Some(bytes) = self.opts.sndbuf {
            let _ = sys::set_sndbuf(stream.as_raw_fd(), bytes);
        }
        let fd = stream.as_raw_fd();
        let deadline = Instant::now() + self.opts.idle_timeout;
        let (slot, epoch) = self.conns.insert(Conn::new(stream, deadline));
        let tok = token(slot, epoch);
        if self.poller.register(fd, tok, Interest::READ).is_err() {
            self.conns.remove(slot);
            self.net.live.fetch_sub(1, Ordering::AcqRel);
            return;
        }
        self.net.accepted.fetch_add(1, Ordering::Relaxed);
        // Exactly one wheel entry per connection for its whole life:
        // fires either re-arm (deadline moved) or close.
        self.wheel.insert(deadline, tok);
    }

    // ---- per-connection events ------------------------------------------

    fn on_conn_event(&mut self, ev: Event) {
        let (slot, epoch) = untoken(ev.token);
        if self.conns.checked_mut(slot, epoch).is_none() {
            return; // stale: the connection this event was for is gone
        }
        if ev.readable {
            self.on_readable(slot);
        }
        if ev.writable {
            self.flush(slot);
        }
    }

    fn on_readable(&mut self, slot: usize) {
        let mut chunk = [0u8; 16 << 10];
        let mut dead = false;
        {
            let Some(conn) = self.conns.slot_mut(slot) else {
                return;
            };
            let mut budget = READ_BURST;
            while budget > 0 {
                if !conn.discard_input && conn.parsed.len() >= PIPELINE_MAX {
                    break; // pipelining cap: stop reading, TCP pushes back
                }
                match conn.stream.read(&mut chunk) {
                    Ok(0) => {
                        conn.peer_eof = true;
                        break;
                    }
                    Ok(n) => {
                        if !conn.discard_input {
                            conn.read_buf.extend_from_slice(&chunk[..n]);
                        }
                        budget -= 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
        }
        if dead {
            self.close(slot);
            return;
        }
        self.parse_ready(slot);
        self.maybe_dispatch(slot);
        self.finalize(slot);
    }

    /// Consume as many complete requests from the buffer as the
    /// pipeline cap allows; a framing error flips the connection into
    /// discard mode with the error response held for ordered delivery.
    fn parse_ready(&mut self, slot: usize) {
        let max_body = self.opts.max_body;
        let Some(conn) = self.conns.slot_mut(slot) else {
            return;
        };
        while !conn.discard_input && conn.parsed.len() < PIPELINE_MAX {
            match conn.try_parse(max_body) {
                ParseStep::NeedMore => break,
                ParseStep::Request(req) => {
                    // A request parsed while an earlier one on this
                    // connection is still unanswered = pipelining.
                    if conn.inflight || !conn.parsed.is_empty() {
                        self.net.pipelined.fetch_add(1, Ordering::Relaxed);
                    }
                    conn.parsed.push_back(req);
                }
                ParseStep::Error(e) => {
                    conn.pending_error = Some(http::response_bytes(
                        e.http_status(),
                        &http::Payload::Json(e.to_json()),
                        false,
                    ));
                    conn.discard_input = true;
                    conn.read_buf.clear();
                    break;
                }
            }
        }
    }

    /// Hand the oldest parsed request to the dispatch pool, at most one
    /// in flight per connection so responses come back in order.
    fn maybe_dispatch(&mut self, slot: usize) {
        let (req, epoch) = {
            let epoch = self.conns.epoch(slot);
            let Some(conn) = self.conns.slot_mut(slot) else {
                return;
            };
            if conn.inflight || conn.close_after_write {
                return;
            }
            let Some(req) = conn.parsed.pop_front() else {
                return;
            };
            conn.inflight = true;
            (req, epoch)
        };
        let keep_alive = req.keep_alive;
        let parsed_us = req.parsed_unix_us;
        let registry = Arc::clone(&self.registry);
        let shared = Arc::clone(&self.shared);
        self.dispatch.submit(move || {
            let picked_us = unix_us();
            let (status, body, nt) = http::route(&registry, &req);
            let routed_us = unix_us();
            let bytes = http::response_bytes(status, &body, keep_alive);
            // Traced infer requests get the net layer's view appended to
            // the engine trace: parse -> dispatch pickup (pool wait) and
            // pickup -> routed (engine submit/wait + serialization). The
            // flush interval is annotated by the event loop once the
            // response bytes drain.
            let trace = nt.map(|nt| {
                nt.tracer.annotate(nt.id, "net_dispatch_wait", parsed_us, picked_us);
                nt.tracer.annotate(nt.id, "net_route", picked_us, routed_us);
                (nt.tracer, nt.id)
            });
            shared
                .completions
                .lock()
                .expect("completion queue poisoned")
                .push(Completion {
                    slot,
                    epoch,
                    bytes,
                    keep_alive,
                    trace,
                });
            shared.waker.wake();
        });
    }

    /// Post-event bookkeeping: release a held framing-error response
    /// once earlier requests are answered, then flush + close/interest.
    fn finalize(&mut self, slot: usize) {
        if let Some(conn) = self.conns.slot_mut(slot) {
            if !conn.inflight && conn.parsed.is_empty() {
                if let Some(bytes) = conn.pending_error.take() {
                    conn.queue_output(&bytes);
                    conn.close_after_write = true;
                }
            }
        }
        self.flush(slot);
    }

    /// Write as much queued output as the socket takes; on a partial
    /// write, register write interest and let readiness finish it.
    fn flush(&mut self, slot: usize) {
        let mut dead = false;
        let mut flushed: Option<(Arc<TraceRecorder>, u64, u64)> = None;
        {
            let Some(conn) = self.conns.slot_mut(slot) else {
                return;
            };
            while conn.pending_out() > 0 {
                match conn.stream.write(&conn.out[conn.out_start..]) {
                    Ok(0) => {
                        dead = true;
                        break;
                    }
                    Ok(n) => conn.out_start += n,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
            if !dead && conn.pending_out() == 0 {
                // The traced response's bytes are fully with the kernel:
                // close out its accept-to-flush timeline.
                flushed = conn.flush_trace.take();
                if conn.close_after_write {
                    dead = true;
                } else if conn.peer_eof && conn.is_quiescent() {
                    dead = true; // half-closed peer, nothing left to say
                }
            }
        }
        if let Some((tracer, id, queued_us)) = flushed {
            tracer.annotate(id, "net_flush", queued_us, unix_us());
        }
        if dead {
            self.close(slot);
        } else {
            self.update_interest(slot);
        }
    }

    /// Re-register with the poller iff the desired interest changed.
    fn update_interest(&mut self, slot: usize) {
        let (fd, tok, desired, current) = {
            let epoch = self.conns.epoch(slot);
            let Some(conn) = self.conns.slot_mut(slot) else {
                return;
            };
            let desired = Interest {
                // Stop reading while the pipeline queue is full; always
                // keep reading in discard mode (draining the peer).
                readable: conn.discard_input || conn.parsed.len() < PIPELINE_MAX,
                writable: conn.pending_out() > 0,
            };
            (
                conn.stream.as_raw_fd(),
                token(slot, epoch),
                desired,
                conn.interest,
            )
        };
        if desired != current && self.poller.reregister(fd, tok, desired).is_ok() {
            if desired.writable && !current.writable {
                // Entering write interest = a partial flush parked for
                // writability to finish it later.
                self.net.flush_resumes.fetch_add(1, Ordering::Relaxed);
            }
            if let Some(conn) = self.conns.slot_mut(slot) {
                conn.interest = desired;
            }
        }
    }

    // ---- completions and timers -----------------------------------------

    fn apply_completions(&mut self) {
        let done = {
            let mut q = self
                .shared
                .completions
                .lock()
                .expect("completion queue poisoned");
            std::mem::take(&mut *q)
        };
        for c in done {
            {
                let Some(conn) = self.conns.checked_mut(c.slot, c.epoch) else {
                    continue; // connection died while the engine worked
                };
                conn.inflight = false;
                conn.queue_output(&c.bytes);
                conn.flush_trace =
                    c.trace.map(|(tracer, id)| (tracer, id, unix_us()));
                if !c.keep_alive {
                    conn.close_after_write = true;
                }
                // The idle window re-arms per completed request, same
                // as the blocking server's per-request deadline.
                conn.deadline = Instant::now() + self.opts.idle_timeout;
            }
            // Bytes past the pipeline cap may already sit in read_buf
            // with the socket quiet — re-parse now that a slot freed.
            self.parse_ready(c.slot);
            self.maybe_dispatch(c.slot);
            self.finalize(c.slot);
        }
    }

    fn fire_timers(&mut self, now: Instant) {
        enum Action {
            Rearm(Instant),
            RearmIdle,
            Close,
        }
        for tok in self.wheel.take_due(now) {
            let (slot, epoch) = untoken(tok);
            let action = {
                let Some(conn) = self.conns.checked_mut(slot, epoch) else {
                    continue; // closed since; entry dies with it
                };
                if conn.deadline > now {
                    Action::Rearm(conn.deadline)
                } else if conn.inflight || !conn.parsed.is_empty() {
                    // Busy in the engine: never reap a working
                    // connection, push the deadline out instead.
                    Action::RearmIdle
                } else {
                    Action::Close
                }
            };
            match action {
                Action::Rearm(d) => self.wheel.insert(d, tok),
                Action::RearmIdle => {
                    let d = now + self.opts.idle_timeout;
                    if let Some(conn) = self.conns.checked_mut(slot, epoch) {
                        conn.deadline = d;
                    }
                    self.wheel.insert(d, tok);
                }
                Action::Close => {
                    self.net.idle_closed.fetch_add(1, Ordering::Relaxed);
                    self.close(slot);
                }
            }
        }
    }

    fn close(&mut self, slot: usize) {
        if let Some(conn) = self.conns.remove(slot) {
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            self.net.live.fetch_sub(1, Ordering::AcqRel);
            // Socket closes when `conn` drops here.
        }
    }
}

/// Best-effort 503 on a just-accepted (still blocking) socket. Runs on
/// a dispatch-pool thread — never on an event loop — because the write
/// can block for up to the whole timeout against a peer that won't read.
fn refuse_overloaded(mut stream: TcpStream, bytes: &[u8]) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
    let _ = stream.write_all(bytes);
}

/// The readiness-loop server: owns the event-loop threads and the
/// dispatch pool; [`HttpServer`](crate::service::http::HttpServer) is
/// the public facade over it.
pub struct NetServer {
    addr: SocketAddr,
    backend: Backend,
    stop: Arc<AtomicBool>,
    loops: Vec<std::thread::JoinHandle<()>>,
    shared: Vec<Arc<LoopShared>>,
    dispatch: Option<Arc<ThreadPool>>,
}

impl NetServer {
    pub fn start(
        registry: Arc<ModelRegistry>,
        addr: &str,
        opts: ServeOptions,
    ) -> Result<NetServer> {
        sys::ensure_fd_limit(opts.max_conns.saturating_mul(2) + 256);
        let backend = opts.net.unwrap_or_else(Backend::from_env);
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("non-blocking listener")?;
        let addr = listener.local_addr()?;
        let n_loops = match opts.event_loops {
            0 => default_threads(),
            n => n,
        }
        .max(1);
        let n_dispatch = match opts.dispatch_threads {
            0 => (default_threads() * 2).max(8),
            n => n,
        };
        let dispatch = Arc::new(ThreadPool::new(n_dispatch));
        let stop = Arc::new(AtomicBool::new(false));
        let net = Arc::clone(registry.net_stats());
        let overload = {
            let e = ServiceError::Overloaded {
                conns: opts.max_conns,
            };
            Arc::new(http::response_bytes(
                e.http_status(),
                &http::Payload::Json(e.to_json()),
                false,
            ))
        };
        let mut loops = Vec::with_capacity(n_loops);
        let mut shared_list = Vec::with_capacity(n_loops);
        for i in 0..n_loops {
            let loop_listener = listener.try_clone().context("cloning listener")?;
            let mut poller = Poller::new(backend)?;
            let (waker, wake_rx) = waker_pair().context("creating loop waker")?;
            poller
                .register(loop_listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)
                .context("registering listener")?;
            poller
                .register(wake_rx.fd(), TOKEN_WAKER, Interest::READ)
                .context("registering waker")?;
            let shared = Arc::new(LoopShared {
                completions: Mutex::new(Vec::new()),
                waker,
            });
            let el = EventLoop {
                poller,
                listener: loop_listener,
                conns: Slab::default(),
                wheel: TimerWheel::new(WHEEL_SLOTS, opts.tick),
                shared: Arc::clone(&shared),
                wake_rx,
                registry: Arc::clone(&registry),
                dispatch: Arc::clone(&dispatch),
                stop: Arc::clone(&stop),
                net: Arc::clone(&net),
                opts,
                overload: Arc::clone(&overload),
            };
            let handle = std::thread::Builder::new()
                .name(format!("adapt-net-{i}"))
                .spawn(move || el.run())
                .context("spawning event loop")?;
            loops.push(handle);
            shared_list.push(shared);
        }
        Ok(NetServer {
            addr,
            backend,
            stop,
            loops,
            shared: shared_list,
            dispatch: Some(dispatch),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Which readiness backend the loops run on.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Stop the loops (dropping every open connection), then drain and
    /// join the dispatch pool.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        for s in &self.shared {
            s.waker.wake();
        }
        for h in self.loops.drain(..) {
            let _ = h.join();
        }
        // Dropping the pool drains queued jobs; their completions go to
        // queues nobody reads, which is fine — the sockets are gone.
        self.dispatch = None;
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}
