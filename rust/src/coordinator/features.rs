//! Table 3: functionality matrix vs the state of the art.
//!
//! The paper's Table 3 is qualitative; we reproduce it as a feature
//! registry where every AdaPT-RS "yes" links to the module that implements
//! it, so the claim is checkable in-code.

use crate::util::fmt;

pub struct FeatureRow {
    pub feature: &'static str,
    pub adapt_rs: &'static str,
    pub tfapprox: &'static str,
    pub proxsim: &'static str,
    pub alwann: &'static str,
    pub typecnn: &'static str,
    /// Where it lives in this repo.
    pub evidence: &'static str,
}

pub const FEATURES: &[FeatureRow] = &[
    FeatureRow {
        feature: "Framework",
        adapt_rs: "Rust+JAX/Pallas",
        tfapprox: "TensorFlow",
        proxsim: "TensorFlow",
        alwann: "TensorFlow",
        typecnn: "C++",
        evidence: "three-layer stack (DESIGN.md)",
    },
    FeatureRow {
        feature: "Backend",
        adapt_rs: "CPU (PJRT)",
        tfapprox: "GPU",
        proxsim: "GPU",
        alwann: "CPU",
        typecnn: "CPU",
        evidence: "rust/src/runtime",
    },
    FeatureRow {
        feature: "Multi-DNN simulation (CNN, LSTM, ...)",
        adapt_rs: "yes",
        tfapprox: "no",
        proxsim: "no",
        alwann: "no",
        typecnn: "no",
        evidence: "9-model zoo: python/compile/model.py",
    },
    FeatureRow {
        feature: "Arbitrary ACU",
        adapt_rs: "yes",
        tfapprox: "no",
        proxsim: "no",
        alwann: "no",
        typecnn: "yes",
        evidence: "rust/src/mult + LUT/functional paths",
    },
    FeatureRow {
        feature: "Quantization calibration",
        adapt_rs: "yes",
        tfapprox: "no",
        proxsim: "no",
        alwann: "yes",
        typecnn: "no",
        evidence: "rust/src/quant/calib.rs (max/pct/MSE/KL)",
    },
    FeatureRow {
        feature: "Approximate-aware re-training",
        adapt_rs: "yes",
        tfapprox: "no",
        proxsim: "yes",
        alwann: "yes",
        typecnn: "yes",
        evidence: "coordinator::ops::train (QAT/STE)",
    },
    FeatureRow {
        feature: "Arbitrary bitwidth / mixed precision",
        adapt_rs: "yes (8/12, per-layer)",
        tfapprox: "8-bit only",
        proxsim: "8-bit only",
        alwann: "8-bit only",
        typecnn: "yes",
        evidence: "graph::retransform Policy overrides",
    },
];

/// Render Table 3.
pub fn table3() -> String {
    let rows: Vec<Vec<String>> = FEATURES
        .iter()
        .map(|r| {
            vec![
                r.feature.to_string(),
                r.adapt_rs.to_string(),
                r.tfapprox.to_string(),
                r.proxsim.to_string(),
                r.alwann.to_string(),
                r.typecnn.to_string(),
                r.evidence.to_string(),
            ]
        })
        .collect();
    fmt::table(
        &[
            "Tool Support",
            "AdaPT-RS",
            "TFApprox",
            "ProxSim",
            "ALWANN",
            "TypeCNN",
            "evidence (this repo)",
        ],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn table_renders_all_features() {
        let t = super::table3();
        assert!(t.contains("Arbitrary ACU"));
        assert!(t.contains("re-training"));
        assert_eq!(t.lines().count(), super::FEATURES.len() + 2);
    }
}
