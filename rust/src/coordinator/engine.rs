//! Request-level inference engine: a pool of dynamic-batching workers in
//! front of a shared bounded request queue.
//!
//! User-facing inference arrives one sample at a time; execution wants
//! fixed-size batches. The engine queues requests in a *bounded* queue
//! (submitters block when it fills — backpressure instead of unbounded
//! memory growth) and runs `workers` batching loops against it. Each
//! worker owns its backend outright — a PJRT [`Runtime`] (not `Send`, so
//! it can never be shared) or a Rust [`Executor`] with its own scratch
//! arena — forms a batch when either the batch fills or `max_wait`
//! expires (classic dynamic batching), pads short batches by repeating
//! the last sample, executes, and fans responses back out.
//!
//! The typed path ([`submit_raw`](InferenceEngine::submit_raw)) speaks
//! [`RawResponse`] / [`ServiceError`](crate::service::ServiceError): each
//! response reports its queue wait, batch compute time, serving worker
//! and plan generation, and every rejection (wrong length, expired
//! deadline, unsupported dtype, backend failure) is a typed variant. The
//! original `Vec<f32>`-in/`Result<Vec<f32>>`-out methods
//! ([`submit`](InferenceEngine::submit) / [`infer`](InferenceEngine::infer))
//! are thin shims over it.
//!
//! The pool is observable and retargetable while it runs:
//! [`stats_snapshot`](InferenceEngine::stats_snapshot) reads per-worker
//! counters and log-scale latency histograms mid-flight (workers publish
//! through atomics). Emulator pools serve a **version table** of
//! installed [`ExecutionPlan`]s rather than one global plan:
//! [`install_version`](InferenceEngine::install_version) publishes an
//! immutable numbered version (weights re-quantized once, adopted via
//! `Arc`), [`activate_version`](InferenceEngine::activate_version) picks
//! which one untagged requests route to, and
//! [`submit_raw_to`](InferenceEngine::submit_raw_to) pins a request to an
//! explicit version — the mechanism under the registry's canary and
//! shadow rollouts. Workers adopt table changes at batch boundaries and
//! partition every gathered batch by version, so no executed batch ever
//! mixes plan versions (or generations).
//! [`swap_plan`](InferenceEngine::swap_plan) remains the one-call
//! install-and-activate shim behind `POST /v1/plan`.
//!
//! With `workers == 1` the batching semantics are exactly the old
//! single-worker engine's. Shutdown drains: `shutdown()` closes the queue
//! (new submits fail), workers keep popping until the queue is empty,
//! flush their final partial batches, and the per-worker [`EngineStats`]
//! are aggregated into [`PoolStats`].

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::ops::{self, InferVariant, ModelState};
use crate::emulator::{Executor, PreparedWeights, ScratchArena, Style, Value};
use crate::graph::{ExecutionPlan, Model};
use crate::lut::LutRegistry;
use crate::obs::trace::Span;
use crate::obs::{LayerProfiler, TraceCtx, TraceOutcome, TraceRecorder};
use crate::runtime::Runtime;
use crate::service::ServiceError;
use crate::tensor::{Tensor, TensorI32};

/// Engine-level outcome of one request on the typed path: the output row
/// plus per-request observability. The service layer wraps this into an
/// [`InferResponse`](crate::service::InferResponse) (adding id / top-k).
#[derive(Clone, Debug)]
pub struct RawResponse {
    pub output: Vec<f32>,
    /// Time the request spent queued before a worker picked it up.
    pub queue_wait: Duration,
    /// Wall-clock of the batch that computed it.
    pub compute: Duration,
    /// Pool worker that served it.
    pub worker: usize,
    /// Plan generation it was computed under.
    pub generation: u64,
    /// Plan version it was computed under (0 on unversioned backends).
    pub version: u64,
}

/// What [`InferenceEngine::submit_raw`] hands back: the receiving end of
/// one request's typed response channel.
pub type RawReceiver = mpsc::Receiver<std::result::Result<RawResponse, ServiceError>>;

/// Where a finished request's answer goes. `Raw` is the typed path;
/// `Flat` backs the legacy `submit`/`infer` shims.
enum Responder {
    Raw(mpsc::Sender<std::result::Result<RawResponse, ServiceError>>),
    Flat(mpsc::Sender<Result<Vec<f32>>>),
}

impl Responder {
    fn send(self, r: std::result::Result<RawResponse, ServiceError>) {
        match self {
            Responder::Raw(tx) => {
                let _ = tx.send(r);
            }
            Responder::Flat(tx) => {
                let _ = tx.send(r.map(|ok| ok.output).map_err(|e| anyhow::anyhow!("{e}")));
            }
        }
    }
}

/// One queued inference request: a flat f32 sample (image/latent/tokens).
struct Request {
    x: Vec<f32>,
    /// Max queue wait before the request is rejected (typed path).
    deadline: Option<Duration>,
    /// Pin to an installed plan version; `None` routes to the active one.
    version: Option<u64>,
    resp: Responder,
    /// When the request entered the queue (for `queue_wait`).
    enqueued: Instant,
    /// Live trace context when the request is traced (sampling on).
    trace: Option<Arc<TraceCtx>>,
}

// ---------------------------------------------------------------------------
// Stats: atomic cells workers publish through + POD snapshots
// ---------------------------------------------------------------------------

/// Log-scale latency histogram buckets: bucket 0 is `< 1 µs`, bucket i
/// covers `[2^(i-1), 2^i) µs`, the last bucket is open-ended (~67 s+).
pub const LAT_BUCKETS: usize = 28;

/// Fixed log2-bucket latency histogram (µs resolution). Cheap enough to
/// record per request on the hot path; coarse enough that p50/p95/p99
/// stay meaningful across nine decades.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyHist {
    pub buckets: Vec<u64>,
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist {
            buckets: vec![0; LAT_BUCKETS],
        }
    }
}

impl LatencyHist {
    /// Bucket index for a duration.
    pub fn bucket_of(d: Duration) -> usize {
        let us = d.as_micros() as u64;
        if us == 0 {
            0
        } else {
            ((64 - us.leading_zeros()) as usize).min(LAT_BUCKETS - 1)
        }
    }

    /// Upper edge of bucket `i` in µs (the percentile estimate returned
    /// for samples landing in it).
    pub fn upper_edge_us(i: usize) -> u64 {
        1u64 << i
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }

    /// Percentile estimate in µs (upper bucket edge), 0 for an empty
    /// histogram. `p` in (0, 1], e.g. 0.5 / 0.95 / 0.99.
    pub fn percentile_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (p * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::upper_edge_us(i);
            }
        }
        Self::upper_edge_us(LAT_BUCKETS - 1)
    }
}

/// Shared per-worker counters: the worker publishes through these atomics
/// so [`InferenceEngine::stats_snapshot`] can read a consistent-enough
/// view mid-run without stopping anything.
#[derive(Default)]
struct StatsCell {
    requests: AtomicUsize,
    batches: AtomicUsize,
    padded_slots: AtomicUsize,
    queue_wait_ns: AtomicU64,
    busy_ns: AtomicU64,
    queue_hist: [AtomicU64; LAT_BUCKETS],
    compute_hist: [AtomicU64; LAT_BUCKETS],
}

impl StatsCell {
    fn record_wait(&self, d: Duration) {
        self.queue_wait_ns
            .fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.queue_hist[LatencyHist::bucket_of(d)].fetch_add(1, Ordering::Relaxed);
    }

    fn record_batch(&self, real: usize, padded: usize, compute: Duration) {
        self.requests.fetch_add(real, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots.fetch_add(padded, Ordering::Relaxed);
        self.busy_ns
            .fetch_add(compute.as_nanos() as u64, Ordering::Relaxed);
        // Per-request compute: every request in the batch paid the full
        // batch wall-clock, so each records one sample.
        self.compute_hist[LatencyHist::bucket_of(compute)]
            .fetch_add(real as u64, Ordering::Relaxed);
    }

    fn snapshot(&self) -> EngineStats {
        let hist = |cells: &[AtomicU64; LAT_BUCKETS]| LatencyHist {
            buckets: cells.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
        };
        EngineStats {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            queue_wait: Duration::from_nanos(self.queue_wait_ns.load(Ordering::Relaxed)),
            busy: Duration::from_nanos(self.busy_ns.load(Ordering::Relaxed)),
            queue_hist: hist(&self.queue_hist),
            compute_hist: hist(&self.compute_hist),
        }
    }
}

/// Per-worker (and aggregated) engine statistics.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests: usize,
    pub batches: usize,
    pub padded_slots: usize,
    /// Total time requests spent queued before a worker picked them up.
    pub queue_wait: Duration,
    /// Time spent assembling + executing batches.
    pub busy: Duration,
    /// Per-request queue-wait distribution (log-scale buckets).
    pub queue_hist: LatencyHist,
    /// Per-request batch-compute distribution (log-scale buckets).
    pub compute_hist: LatencyHist,
}

impl EngineStats {
    fn merge(&mut self, other: &EngineStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.padded_slots += other.padded_slots;
        self.queue_wait += other.queue_wait;
        self.busy += other.busy;
        self.queue_hist.merge(&other.queue_hist);
        self.compute_hist.merge(&other.compute_hist);
    }
}

/// Aggregate + per-worker stats, from [`InferenceEngine::shutdown`] (final)
/// or [`InferenceEngine::stats_snapshot`] (live, mid-run).
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// Sums over all workers.
    pub total: EngineStats,
    /// One entry per pool worker, in spawn order.
    pub per_worker: Vec<EngineStats>,
    /// Current plan generation (0 until the first successful hot-swap).
    pub generation: u64,
}

impl PoolStats {
    /// (p50, p95, p99) of per-request queue wait, in µs.
    pub fn queue_wait_percentiles_us(&self) -> (u64, u64, u64) {
        let h = &self.total.queue_hist;
        (
            h.percentile_us(0.50),
            h.percentile_us(0.95),
            h.percentile_us(0.99),
        )
    }

    /// (p50, p95, p99) of per-request batch compute, in µs.
    pub fn compute_percentiles_us(&self) -> (u64, u64, u64) {
        let h = &self.total.compute_hist;
        (
            h.percentile_us(0.50),
            h.percentile_us(0.95),
            h.percentile_us(0.99),
        )
    }
}

// ---------------------------------------------------------------------------
// Backend specs + config
// ---------------------------------------------------------------------------

/// What each pool worker runs batches on. PJRT state is not `Send`, so a
/// worker *constructs* its backend on its own thread from this spec.
#[derive(Clone)]
pub enum BackendSpec {
    /// The AOT executables through a per-worker PJRT [`Runtime`].
    Pjrt {
        artifacts: PathBuf,
        model: String,
        variant: InferVariant,
        /// ACU name when `variant == ApproxLut`.
        acu: Option<String>,
    },
    /// The in-process Rust emulator (artifact-free): every worker owns its
    /// own [`Executor`] + scratch arena over this shared spec.
    Emulator(Arc<EmulatorSpec>),
}

/// Spec for [`BackendSpec::Emulator`] workers. Shared read-only (`Arc`);
/// the pool quantizes the weights once at [`InferenceEngine::start`] and
/// every worker adopts the shared [`PreparedWeights`].
pub struct EmulatorSpec {
    pub model: Model,
    pub params: Vec<Tensor>,
    pub plan: ExecutionPlan,
    pub act_scales: Vec<f32>,
    pub luts: LutRegistry,
    /// Engine batch size (the PJRT backend takes it from the manifest).
    pub batch: usize,
    /// GEMM threads inside one worker's forward pass.
    pub gemm_threads: usize,
}

/// Configuration for [`InferenceEngine`].
pub struct EngineConfig {
    pub backend: BackendSpec,
    /// Max time a worker holds a partial batch before flushing.
    pub max_wait: Duration,
    /// Pool size. Default [`default_threads`](crate::util::threadpool::default_threads)
    /// (`ADAPT_THREADS` env); 1 reproduces the old single-worker engine.
    pub workers: usize,
    /// Bounded request-queue depth; [`InferenceEngine::submit`] blocks
    /// while the queue is full (backpressure).
    pub queue_depth: usize,
}

/// Default bounded queue depth (requests, not batches).
pub const DEFAULT_QUEUE_DEPTH: usize = 256;

impl EngineConfig {
    /// PJRT-backed engine with default pool sizing.
    pub fn pjrt(
        artifacts: PathBuf,
        model: impl Into<String>,
        variant: InferVariant,
        acu: Option<String>,
    ) -> EngineConfig {
        EngineConfig {
            backend: BackendSpec::Pjrt {
                artifacts,
                model: model.into(),
                variant,
                acu,
            },
            max_wait: Duration::from_millis(20),
            workers: crate::util::threadpool::default_threads(),
            queue_depth: DEFAULT_QUEUE_DEPTH,
        }
    }

    /// Emulator-backed engine with default pool sizing.
    pub fn emulator(spec: EmulatorSpec) -> EngineConfig {
        EngineConfig {
            backend: BackendSpec::Emulator(Arc::new(spec)),
            max_wait: Duration::from_millis(20),
            workers: crate::util::threadpool::default_threads(),
            queue_depth: DEFAULT_QUEUE_DEPTH,
        }
    }
}

// ---------------------------------------------------------------------------
// Shared bounded request queue
// ---------------------------------------------------------------------------

struct QueueState {
    items: VecDeque<Request>,
    closed: bool,
}

/// MPMC bounded queue: submitters block on `not_full` (backpressure),
/// workers block on `not_empty`. Closing wakes everyone; workers drain
/// whatever is left before exiting.
struct SharedQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

/// Outcome of a deadline-bounded pop (the batch-gathering wait).
enum Popped {
    Item(Request),
    TimedOut,
    /// Queue closed and fully drained.
    Drained,
}

impl SharedQueue {
    fn new(cap: usize) -> SharedQueue {
        SharedQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push; applies backpressure while full. Errors once closed.
    fn push(&self, req: Request) -> std::result::Result<(), ServiceError> {
        let mut st = self.state.lock().expect("engine queue poisoned");
        loop {
            if st.closed {
                return Err(ServiceError::ShuttingDown);
            }
            if st.items.len() < self.cap {
                break;
            }
            st = self.not_full.wait(st).expect("engine queue poisoned");
        }
        st.items.push_back(req);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push: `Ok(false)` when the queue is full (instead of
    /// backpressure). Errors once closed.
    fn try_push(&self, req: Request) -> std::result::Result<bool, ServiceError> {
        let mut st = self.state.lock().expect("engine queue poisoned");
        if st.closed {
            return Err(ServiceError::ShuttingDown);
        }
        if st.items.len() >= self.cap {
            return Ok(false);
        }
        st.items.push_back(req);
        drop(st);
        self.not_empty.notify_one();
        Ok(true)
    }

    /// Requests currently queued (for health / stats reporting).
    fn len(&self) -> usize {
        self.state.lock().expect("engine queue poisoned").items.len()
    }

    /// Blocking pop for the first request of a batch. `None` only when the
    /// queue is closed *and* drained.
    fn pop_blocking(&self) -> Option<Request> {
        let mut st = self.state.lock().expect("engine queue poisoned");
        loop {
            if let Some(r) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(r);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).expect("engine queue poisoned");
        }
    }

    /// Pop one more request for the current batch, waiting at most until
    /// `deadline`.
    fn pop_until(&self, deadline: Instant) -> Popped {
        let mut st = self.state.lock().expect("engine queue poisoned");
        loop {
            if let Some(r) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Popped::Item(r);
            }
            if st.closed {
                return Popped::Drained;
            }
            let now = Instant::now();
            if now >= deadline {
                return Popped::TimedOut;
            }
            let (guard, _timeout) = self
                .not_empty
                .wait_timeout(st, deadline - now)
                .expect("engine queue poisoned");
            st = guard;
        }
    }

    fn close(&self) {
        let mut st = self.state.lock().expect("engine queue poisoned");
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Plan version table (hot-swap, canary and shadow routing)
// ---------------------------------------------------------------------------

/// Version number the starting plan is installed under.
pub const INITIAL_VERSION: u64 = 1;

/// One installed, immutable plan version: the plan, its shared
/// pre-quantized weight tables (workers clone the `Arc`-backed fields,
/// never re-quantize), and the generation number assigned at install
/// time — the `generation` every response computed under this version
/// carries (the v1 hot-swap counter).
struct VersionPlan {
    version: u64,
    gen_no: u64,
    plan: ExecutionPlan,
    prepared: PreparedWeights,
}

/// The servable version set a pool publishes to its workers. Entries are
/// immutable once inserted; only membership and `active` ever change.
struct VersionTable {
    entries: BTreeMap<u64, Arc<VersionPlan>>,
    /// Version untagged requests route to.
    active: u64,
}

/// Shared swap cell: `epoch` is the cheap per-batch staleness check
/// (bumped on every install / activate / retire); `table` holds the
/// published set; `installs` hands out generation numbers. Every
/// emulator worker adopts table changes at its next batch boundary.
struct SwapState {
    epoch: AtomicU64,
    installs: AtomicU64,
    table: Mutex<VersionTable>,
}

// ---------------------------------------------------------------------------
// Engine pool
// ---------------------------------------------------------------------------

/// Handle to the worker pool.
pub struct InferenceEngine {
    queue: Arc<SharedQueue>,
    workers: Vec<std::thread::JoinHandle<()>>,
    cells: Vec<Arc<StatsCell>>,
    /// Present for emulator backends (the swappable ones).
    swap: Option<Arc<SwapState>>,
    emu_spec: Option<Arc<EmulatorSpec>>,
    out_dim: usize,
    in_len: usize,
    /// Request-trace recorder (tail-based sampling + retention ring).
    tracer: Arc<TraceRecorder>,
    /// Per-layer kernel profiler shared by every emulator executor in
    /// the pool (`ADAPT_PROFILE=1` enables it).
    profiler: Arc<LayerProfiler>,
}

impl InferenceEngine {
    /// Start the pool. Every worker compiles/prepares its backend before
    /// the call returns; the first setup failure aborts the whole pool.
    ///
    /// Emulator backends quantize the model's weights exactly **once**
    /// here ([`Executor::prepare_weights`]); every worker adopts the same
    /// shared tables behind an `Arc` instead of re-quantizing its own
    /// copy — the shared quantized-weight cache for pool workers.
    pub fn start(cfg: EngineConfig) -> Result<InferenceEngine> {
        let n_workers = cfg.workers.max(1);
        let queue = Arc::new(SharedQueue::new(cfg.queue_depth));
        // Shared quantized-weight cache + swap cell (emulator backends
        // only). Failing here (e.g. an unknown ACU in the plan) aborts the
        // start just like a per-worker setup failure used to.
        let (swap, emu_spec) = match &cfg.backend {
            BackendSpec::Emulator(spec) => {
                let prepared = Executor::prepare_weights(
                    &spec.model,
                    &spec.params,
                    &spec.plan,
                    &spec.luts,
                )?;
                let mut entries = BTreeMap::new();
                entries.insert(
                    INITIAL_VERSION,
                    Arc::new(VersionPlan {
                        version: INITIAL_VERSION,
                        gen_no: 0,
                        plan: spec.plan.clone(),
                        prepared,
                    }),
                );
                let swap = Arc::new(SwapState {
                    epoch: AtomicU64::new(0),
                    installs: AtomicU64::new(1),
                    table: Mutex::new(VersionTable {
                        entries,
                        active: INITIAL_VERSION,
                    }),
                });
                (Some(swap), Some(Arc::clone(spec)))
            }
            BackendSpec::Pjrt { .. } => (None, None),
        };
        let cells: Vec<Arc<StatsCell>> = (0..n_workers)
            .map(|_| Arc::new(StatsCell::default()))
            .collect();
        let tracer = Arc::new(TraceRecorder::from_env());
        let profiler = Arc::new(LayerProfiler::from_env());
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize)>>();
        let mut workers = Vec::with_capacity(n_workers);
        for (wi, cell) in cells.iter().enumerate() {
            let queue = Arc::clone(&queue);
            let ready = ready_tx.clone();
            let backend = cfg.backend.clone();
            let swap = swap.clone();
            let cell = Arc::clone(cell);
            let max_wait = cfg.max_wait;
            let tracer = Arc::clone(&tracer);
            let profiler = Arc::clone(&profiler);
            let handle = std::thread::Builder::new()
                .name(format!("adapt-engine-{wi}"))
                .spawn(move || match backend {
                    BackendSpec::Pjrt {
                        artifacts,
                        model,
                        variant,
                        acu,
                    } => pjrt_worker(
                        &artifacts, &model, variant, acu, &queue, max_wait, wi, &cell, &tracer,
                        &ready,
                    ),
                    BackendSpec::Emulator(spec) => {
                        let swap = swap.expect("emulator swap state built above");
                        emulator_worker(
                            &spec, &swap, &queue, max_wait, wi, &cell, &tracer, &profiler, &ready,
                        )
                    }
                })
                .context("spawning engine worker")?;
            workers.push(handle);
        }
        drop(ready_tx);

        let (mut out_dim, mut in_len) = (0usize, 0usize);
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok((d, p))) => {
                    out_dim = d;
                    in_len = p;
                }
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!("engine worker died before ready"));
                    }
                }
            }
        }
        if let Some(e) = first_err {
            queue.close();
            for h in workers {
                let _ = h.join();
            }
            return Err(e);
        }
        Ok(InferenceEngine {
            queue,
            workers,
            cells,
            swap,
            emu_spec,
            out_dim,
            in_len,
            tracer,
            profiler,
        })
    }

    /// The pool's trace recorder (sampling knobs + retained traces).
    pub fn tracer(&self) -> &Arc<TraceRecorder> {
        &self.tracer
    }

    /// The pool's shared per-layer kernel profiler.
    pub fn profiler(&self) -> &Arc<LayerProfiler> {
        &self.profiler
    }

    /// Output dimension per sample.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Flat per-sample input length.
    pub fn input_len(&self) -> usize {
        self.in_len
    }

    /// Number of pool workers.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Workers whose threads are still running (a worker only exits when
    /// the queue closes or it panics — fewer alive than configured on an
    /// open queue means the pool is degraded).
    pub fn alive_workers(&self) -> usize {
        self.workers.iter().filter(|h| !h.is_finished()).count()
    }

    /// Requests currently waiting in the shared queue.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Current plan generation: the active version's install number
    /// (0 until the first successful hot-swap or activation of a newer
    /// version — the v1 counter semantics).
    pub fn generation(&self) -> u64 {
        self.swap
            .as_ref()
            .map(|s| {
                let t = s.table.lock().expect("swap state poisoned");
                t.entries.get(&t.active).map(|vp| vp.gen_no).unwrap_or(0)
            })
            .unwrap_or(0)
    }

    /// The plan version untagged requests currently route to (0 on
    /// unversioned backends — PJRT executables bake their plan in).
    pub fn active_version(&self) -> u64 {
        self.swap
            .as_ref()
            .map(|s| s.table.lock().expect("swap state poisoned").active)
            .unwrap_or(0)
    }

    /// Whether a plan version is currently installed (allocation-free —
    /// routing-path check).
    pub fn has_version(&self, version: u64) -> bool {
        self.swap
            .as_ref()
            .map(|s| {
                s.table
                    .lock()
                    .expect("swap state poisoned")
                    .entries
                    .contains_key(&version)
            })
            .unwrap_or(false)
    }

    /// Every installed (servable) plan version, ascending.
    pub fn installed_versions(&self) -> Vec<u64> {
        self.swap
            .as_ref()
            .map(|s| {
                s.table
                    .lock()
                    .expect("swap state poisoned")
                    .entries
                    .keys()
                    .copied()
                    .collect()
            })
            .unwrap_or_default()
    }

    /// The swap cell + emulator spec, or the typed "can't version PJRT"
    /// rejection every version operation shares.
    fn versioned(
        &self,
    ) -> std::result::Result<(&Arc<SwapState>, &Arc<EmulatorSpec>), ServiceError> {
        match (&self.swap, &self.emu_spec) {
            (Some(s), Some(e)) => Ok((s, e)),
            _ => Err(ServiceError::PlanRejected(
                "plan versioning requires the emulator backend (PJRT executables bake their plan in)"
                    .into(),
            )),
        }
    }

    /// Install `plan` as immutable version `version`: validate it by
    /// re-quantizing the weights **once** (same shared-`Arc` cache as
    /// startup) and publish it to the workers *without* routing any
    /// traffic to it. Returns the generation number assigned to the
    /// version. Re-installing an existing version with the same plan is
    /// an idempotent no-op; a different plan under a taken number is
    /// rejected (versions are immutable).
    pub fn install_version(
        &self,
        version: u64,
        plan: ExecutionPlan,
    ) -> std::result::Result<u64, ServiceError> {
        let (swap, spec) = self.versioned()?;
        if let Some(vp) = swap
            .table
            .lock()
            .expect("swap state poisoned")
            .entries
            .get(&version)
        {
            if vp.plan != plan {
                return Err(ServiceError::PlanRejected(format!(
                    "version {version} is already installed with a different plan (versions are immutable)"
                )));
            }
            return Ok(vp.gen_no);
        }
        let prepared = Executor::prepare_weights(&spec.model, &spec.params, &plan, &spec.luts)
            .map_err(|e| ServiceError::PlanRejected(format!("{e:#}")))?;
        let mut table = swap.table.lock().expect("swap state poisoned");
        if let Some(vp) = table.entries.get(&version) {
            // Raced with another installer of the same number.
            if vp.plan != plan {
                return Err(ServiceError::PlanRejected(format!(
                    "version {version} is already installed with a different plan (versions are immutable)"
                )));
            }
            return Ok(vp.gen_no);
        }
        let gen_no = swap.installs.fetch_add(1, Ordering::Relaxed);
        table.entries.insert(
            version,
            Arc::new(VersionPlan {
                version,
                gen_no,
                plan,
                prepared,
            }),
        );
        drop(table);
        swap.epoch.fetch_add(1, Ordering::Release);
        Ok(gen_no)
    }

    /// Route untagged traffic to installed version `version` from the
    /// next batch boundary on. Returns its generation number. In-flight
    /// and already-queued requests may still be served by the previous
    /// active version; no batch mixes the two.
    pub fn activate_version(&self, version: u64) -> std::result::Result<u64, ServiceError> {
        let (swap, _) = self.versioned()?;
        let mut table = swap.table.lock().expect("swap state poisoned");
        let Some(vp) = table.entries.get(&version) else {
            return Err(ServiceError::NoSuchVersion { version });
        };
        let gen_no = vp.gen_no;
        table.active = version;
        drop(table);
        swap.epoch.fetch_add(1, Ordering::Release);
        Ok(gen_no)
    }

    /// Drop an installed version (workers release its executors and
    /// prepared weights at their next batch boundary). The active
    /// version cannot be retired; in-flight requests pinned to the
    /// retired version get a typed `no_such_version` rejection.
    pub fn retire_version(&self, version: u64) -> std::result::Result<(), ServiceError> {
        let (swap, _) = self.versioned()?;
        let mut table = swap.table.lock().expect("swap state poisoned");
        if table.active == version {
            return Err(ServiceError::PlanRejected(format!(
                "cannot retire the active version {version}"
            )));
        }
        table.entries.remove(&version);
        drop(table);
        swap.epoch.fetch_add(1, Ordering::Release);
        Ok(())
    }

    /// The shared emulator spec, when this pool runs the emulator backend
    /// (the service layer needs the [`Model`] to validate incoming plans).
    pub fn emulator_spec(&self) -> Option<&Arc<EmulatorSpec>> {
        self.emu_spec.as_ref()
    }

    /// Typed submit: returns a receiver for the request's [`RawResponse`].
    /// Blocks while the request queue is full (backpressure).
    pub fn submit_raw(
        &self,
        x: Vec<f32>,
        deadline: Option<Duration>,
    ) -> std::result::Result<RawReceiver, ServiceError> {
        self.submit_raw_to(x, deadline, None)
    }

    /// Typed submit pinned to an installed plan version (`None` routes to
    /// the active one) — the primitive under canary and shadow rollouts.
    /// Unknown versions fail fast here; the worker re-checks at execution
    /// time in case the version is retired while the request queues.
    pub fn submit_raw_to(
        &self,
        x: Vec<f32>,
        deadline: Option<Duration>,
        version: Option<u64>,
    ) -> std::result::Result<RawReceiver, ServiceError> {
        self.submit_raw_traced(x, deadline, version, None)
    }

    /// [`submit_raw_to`](Self::submit_raw_to) carrying an optional trace
    /// context (begun via [`tracer`](Self::tracer) with the request id).
    /// A rejected submit finishes the trace with the matching error code
    /// so overloads are always retained by the tail sampler.
    pub fn submit_raw_traced(
        &self,
        x: Vec<f32>,
        deadline: Option<Duration>,
        version: Option<u64>,
        trace: Option<Arc<TraceCtx>>,
    ) -> std::result::Result<RawReceiver, ServiceError> {
        if let Some(v) = version {
            let (swap, _) = self.versioned()?;
            if !swap
                .table
                .lock()
                .expect("swap state poisoned")
                .entries
                .contains_key(&v)
            {
                if let Some(tr) = &trace {
                    self.tracer
                        .finish(tr, TraceOutcome::Error("no_such_version"));
                }
                return Err(ServiceError::NoSuchVersion { version: v });
            }
        }
        let (resp, rx) = mpsc::channel();
        let pushed = self.queue.push(Request {
            x,
            deadline,
            version,
            resp: Responder::Raw(resp),
            enqueued: Instant::now(),
            trace: trace.clone(),
        });
        if let Err(e) = pushed {
            if let Some(tr) = &trace {
                self.tracer.finish(tr, TraceOutcome::Error(e.code()));
            }
            return Err(e);
        }
        Ok(rx)
    }

    /// Non-blocking variant of [`submit_raw_to`](Self::submit_raw_to):
    /// returns `Ok(None)` when the bounded queue is full instead of
    /// applying backpressure — best-effort traffic (shadow mirrors) uses
    /// it so it can never stall a serving thread.
    pub fn try_submit_raw_to(
        &self,
        x: Vec<f32>,
        deadline: Option<Duration>,
        version: Option<u64>,
    ) -> std::result::Result<Option<RawReceiver>, ServiceError> {
        self.try_submit_raw_traced(x, deadline, version, None)
    }

    /// [`try_submit_raw_to`](Self::try_submit_raw_to) carrying an
    /// optional trace context. A full queue finishes the trace as an
    /// `overloaded` error (always retained by the tail sampler).
    pub fn try_submit_raw_traced(
        &self,
        x: Vec<f32>,
        deadline: Option<Duration>,
        version: Option<u64>,
        trace: Option<Arc<TraceCtx>>,
    ) -> std::result::Result<Option<RawReceiver>, ServiceError> {
        if let Some(v) = version {
            let (swap, _) = self.versioned()?;
            if !swap
                .table
                .lock()
                .expect("swap state poisoned")
                .entries
                .contains_key(&v)
            {
                if let Some(tr) = &trace {
                    self.tracer
                        .finish(tr, TraceOutcome::Error("no_such_version"));
                }
                return Err(ServiceError::NoSuchVersion { version: v });
            }
        }
        let (resp, rx) = mpsc::channel();
        let accepted = self.queue.try_push(Request {
            x,
            deadline,
            version,
            resp: Responder::Raw(resp),
            enqueued: Instant::now(),
            trace: trace.clone(),
        });
        match accepted {
            Ok(true) => Ok(Some(rx)),
            Ok(false) => {
                if let Some(tr) = &trace {
                    self.tracer.finish(tr, TraceOutcome::Error("overloaded"));
                }
                Ok(None)
            }
            Err(e) => {
                if let Some(tr) = &trace {
                    self.tracer.finish(tr, TraceOutcome::Error(e.code()));
                }
                Err(e)
            }
        }
    }

    /// Submit one sample; returns a receiver for its output row. Blocks
    /// while the request queue is full (backpressure).
    ///
    /// Legacy shim over the typed path: drops the per-request metadata and
    /// flattens [`ServiceError`] into `anyhow::Error`.
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let (resp, rx) = mpsc::channel();
        self.queue
            .push(Request {
                x,
                deadline: None,
                version: None,
                resp: Responder::Flat(resp),
                enqueued: Instant::now(),
                trace: None,
            })
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(rx)
    }

    /// Blocking convenience wrapper around [`submit`](Self::submit).
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(x)?.recv().context("engine dropped request")?
    }

    /// Live stats: per-worker counters + latency histograms read through
    /// the workers' atomics, without stopping or draining anything.
    /// [`shutdown`](Self::shutdown) returns the same shape, final.
    pub fn stats_snapshot(&self) -> PoolStats {
        let per_worker: Vec<EngineStats> = self.cells.iter().map(|c| c.snapshot()).collect();
        let mut total = EngineStats::default();
        for s in &per_worker {
            total.merge(s);
        }
        PoolStats {
            total,
            per_worker,
            generation: self.generation(),
        }
    }

    /// Hot-swap the execution plan on a live pool (emulator backends):
    /// install `plan` under the next free version number, activate it,
    /// and retire every other version in one atomic table update — the
    /// `POST /v1/plan` semantics, keeping exactly one live plan like the
    /// pre-registry engine did (no unbounded growth across repeated
    /// swaps; registry-managed rollouts use install/activate/retire
    /// directly and keep their own rollback target). Every worker adopts
    /// at its next batch boundary, so no batch mixes generations;
    /// in-flight and already-queued requests may still be served by the
    /// previous generation. Returns the new generation.
    pub fn swap_plan(&self, plan: ExecutionPlan) -> std::result::Result<u64, ServiceError> {
        let (swap, spec) = self.versioned()?;
        let prepared = Executor::prepare_weights(&spec.model, &spec.params, &plan, &spec.luts)
            .map_err(|e| ServiceError::PlanRejected(format!("{e:#}")))?;
        let mut table = swap.table.lock().expect("swap state poisoned");
        let version = table.entries.keys().next_back().copied().unwrap_or(0) + 1;
        let gen_no = swap.installs.fetch_add(1, Ordering::Relaxed);
        table.entries.clear();
        table.entries.insert(
            version,
            Arc::new(VersionPlan {
                version,
                gen_no,
                plan,
                prepared,
            }),
        );
        table.active = version;
        drop(table);
        // Publish after the guarded update: a worker that sees the new
        // epoch always finds the new table under the lock.
        swap.epoch.fetch_add(1, Ordering::Release);
        Ok(gen_no)
    }

    /// Stop the pool: close the queue, let every worker drain + flush, and
    /// aggregate their stats.
    pub fn shutdown(mut self) -> Result<PoolStats> {
        self.queue.close();
        for h in self.workers.drain(..) {
            h.join()
                .map_err(|_| anyhow::anyhow!("engine worker panicked"))?;
        }
        Ok(self.stats_snapshot())
    }
}

impl Drop for InferenceEngine {
    fn drop(&mut self) {
        if !self.workers.is_empty() {
            self.queue.close();
            for h in self.workers.drain(..) {
                let _ = h.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

/// The shared dynamic-batching loop: gather up to `bs` requests (first one
/// blocking, the rest until `max_wait`), partition by requested plan
/// version, pad + run `infer` per version group, fan out. `per` is the
/// flat per-sample input length. `infer` takes the group's version pin
/// (`None` = active) and returns the flat output plus the (generation,
/// version) it actually computed under — so no executed batch ever mixes
/// plan versions.
#[allow(clippy::too_many_arguments)]
fn batching_loop<F>(
    queue: &SharedQueue,
    bs: usize,
    per: usize,
    max_wait: Duration,
    worker: usize,
    cell: &StatsCell,
    tracer: &TraceRecorder,
    mut infer: F,
) where
    F: FnMut(Option<u64>, &[f32]) -> std::result::Result<(Vec<f32>, u64, u64), ServiceError>,
{
    let mut pending: Vec<(Request, Duration)> = Vec::with_capacity(bs);
    let mut group: Vec<(Request, Duration)> = Vec::with_capacity(bs);
    let mut flat: Vec<f32> = Vec::with_capacity(bs * per);
    // A malformed or expired request must never take down the worker (or
    // the rest of its batch): answer it with a typed error and keep it
    // out of the batch. Traced rejects record their queue span and
    // finish immediately — errors are always retained by the tail
    // sampler.
    let admit = |r: Request, pending: &mut Vec<(Request, Duration)>| {
        let picked = Instant::now();
        let waited = picked.duration_since(r.enqueued);
        cell.record_wait(waited);
        if let Some(tr) = &r.trace {
            let start = tr.offset_us(r.enqueued);
            tr.span("queue", start, tr.offset_us(picked));
        }
        if r.x.len() != per {
            let err = ServiceError::WrongInputLength {
                got: r.x.len(),
                expected: per,
            };
            if let Some(tr) = &r.trace {
                tracer.finish(tr, TraceOutcome::Error(err.code()));
            }
            r.resp.send(Err(err));
            return;
        }
        if let Some(d) = r.deadline {
            if waited >= d {
                let err = ServiceError::DeadlineExceeded {
                    waited_ms: waited.as_millis() as u64,
                };
                if let Some(tr) = &r.trace {
                    tracer.finish(tr, TraceOutcome::Error(err.code()));
                }
                r.resp.send(Err(err));
                return;
            }
        }
        pending.push((r, waited));
    };
    loop {
        // Block for the first request of a batch (or drained shutdown).
        let Some(first) = queue.pop_blocking() else {
            break;
        };
        admit(first, &mut pending);
        let deadline = Instant::now() + max_wait;
        // A close() during the gather must still flush this batch *and
        // then* let the outer loop observe the drained queue and stop.
        let mut drained = false;
        while pending.len() < bs {
            match queue.pop_until(deadline) {
                Popped::Item(r) => admit(r, &mut pending),
                Popped::TimedOut => break,
                Popped::Drained => {
                    drained = true;
                    break;
                }
            }
        }
        if pending.is_empty() {
            // Every gathered request was malformed; nothing to execute.
            if drained {
                break;
            }
            continue;
        }

        // Execute the gathered requests in per-version groups (arrival
        // order preserved), so no executed batch ever mixes plan
        // versions. The dominant case — every request on the same
        // version — is a zero-allocation buffer swap; only a genuinely
        // mixed gather (a live canary/shadow split) pays a partition.
        while !pending.is_empty() {
            let key = pending[0].0.version;
            if pending.iter().all(|(r, _)| r.version == key) {
                std::mem::swap(&mut pending, &mut group);
            } else {
                let mut rest: Vec<(Request, Duration)> = Vec::with_capacity(pending.len());
                for p in pending.drain(..) {
                    if p.0.version == key {
                        group.push(p);
                    } else {
                        rest.push(p);
                    }
                }
                pending = rest;
            }

            let t0 = Instant::now();
            flat.clear();
            for (r, _) in &group {
                flat.extend_from_slice(&r.x);
            }
            let real = group.len();
            for _ in real..bs {
                let last_start = (real - 1) * per;
                flat.extend_from_within(last_start..last_start + per);
            }

            let result = infer(key, &flat);
            let compute = t0.elapsed();
            cell.record_batch(real, bs - real, compute);

            // Spans for traced members: `batch` covers pickup → batch
            // launch (gather/pad), `execute` the shared forward. They
            // share boundary offsets with the queue span, so every
            // trace's intervals are monotone and non-overlapping.
            let trace_spans = |r: &Request, waited: Duration, exec: Option<(u64, u64)>| {
                let Some(tr) = &r.trace else { return };
                let pickup = tr.offset_us(r.enqueued) + waited.as_micros() as u64;
                let exec_start = tr.offset_us(t0).max(pickup);
                tr.push(Span {
                    name: "batch",
                    start_us: pickup,
                    end_us: exec_start,
                    worker: None,
                    version: None,
                    generation: None,
                    batch: Some(real),
                });
                let (generation, version) = match exec {
                    Some((g, v)) => (Some(g), Some(v)),
                    None => (None, None),
                };
                tr.push(Span {
                    name: "execute",
                    start_us: exec_start,
                    end_us: exec_start + compute.as_micros() as u64,
                    worker: Some(worker),
                    version,
                    generation,
                    batch: Some(real),
                });
            };

            match result {
                Ok((out, generation, version)) => {
                    let row = out.len() / bs;
                    for (i, (r, waited)) in group.drain(..).enumerate() {
                        trace_spans(&r, waited, Some((generation, version)));
                        if let Some(tr) = r.trace.clone() {
                            tracer.finish(&tr, TraceOutcome::Ok);
                        }
                        r.resp.send(Ok(RawResponse {
                            output: out[i * row..(i + 1) * row].to_vec(),
                            queue_wait: waited,
                            compute,
                            worker,
                            generation,
                            version,
                        }));
                    }
                }
                Err(e) => {
                    for (r, waited) in group.drain(..) {
                        trace_spans(&r, waited, None);
                        if let Some(tr) = r.trace.clone() {
                            tracer.finish(&tr, TraceOutcome::Error(e.code()));
                        }
                        r.resp.send(Err(e.clone()));
                    }
                }
            }
        }
        if drained {
            break;
        }
    }
}

/// PJRT-backed worker: owns its own `Runtime` (PJRT is not `Send`),
/// compiles the executable, then serves the shared queue.
#[allow(clippy::too_many_arguments)]
fn pjrt_worker(
    artifacts: &std::path::Path,
    model: &str,
    variant: InferVariant,
    acu: Option<String>,
    queue: &SharedQueue,
    max_wait: Duration,
    worker: usize,
    cell: &StatsCell,
    tracer: &TraceRecorder,
    ready: &mpsc::Sender<Result<(usize, usize)>>,
) {
    let setup = (|| -> Result<(Runtime, ModelState, Option<xla::Literal>)> {
        let mut rt = Runtime::open(artifacts)?;
        let mut st = ModelState::load_best(&rt, model)?;
        let lut_lit = match (variant, &acu) {
            (InferVariant::ApproxLut, Some(acu)) => Some(ops::load_lut_lit(&rt, acu)?),
            (InferVariant::ApproxLut, None) => {
                anyhow::bail!("ApproxLut engine needs an ACU name")
            }
            _ => None,
        };
        if variant != InferVariant::Fp32 {
            // Engine-side quick calibration on the model's dataset.
            let ds = crate::data::load(&st.model.dataset, &crate::data::Sizes::small());
            ops::calibrate(
                &mut rt,
                &mut st,
                &ds,
                2,
                crate::quant::calib::CalibratorKind::Percentile,
                0.999,
            )?;
        }
        rt.prepare(model, variant.artifact())?;
        Ok((rt, st, lut_lit))
    })();

    let (mut rt, st, lut_lit) = match setup {
        Ok(v) => {
            let per: usize = v.1.model.input_shape.iter().product();
            let _ = ready.send(Ok((v.1.model.out_dim, per)));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let bs = rt.manifest.batch;
    let per: usize = st.model.input_shape.iter().product();
    batching_loop(queue, bs, per, max_wait, worker, cell, tracer, |version, flat| {
        // PJRT executables bake their plan in: always generation 0 and
        // unversioned; version-pinned requests are rejected per-request.
        if let Some(v) = version {
            return Err(ServiceError::NoSuchVersion { version: v });
        }
        (|| -> Result<Vec<f32>> {
            let x = ops::flat_batch_input(&st.model, bs, flat)?;
            ops::infer_batch(&mut rt, &st, variant, &x, lut_lit.as_ref())
        })()
        .map(|out| (out, 0u64, 0u64))
        .map_err(|e| ServiceError::Backend(format!("{e:#}")))
    });
}

/// Build one emulator executor for a version's plan + shared weights,
/// wired to the pool's shared per-layer profiler.
fn emulator_executor<'m>(
    spec: &'m EmulatorSpec,
    vp: &VersionPlan,
    profiler: &Arc<LayerProfiler>,
) -> Result<Executor<'m>> {
    let mut exec = Executor::with_prepared(
        &spec.model,
        spec.params.clone(),
        vp.plan.clone(),
        spec.act_scales.clone(),
        Style::Optimized {
            threads: spec.gemm_threads.max(1),
        },
        vp.prepared.clone(),
        ScratchArena::new(),
    )?;
    exec.set_profiler(Some(Arc::clone(profiler)));
    Ok(exec)
}

/// Emulator-backed worker: adopts the pool's shared quantized weights
/// (one `Arc` clone per version, no re-quantization) and owns one
/// executor + scratch arena per installed version it has actually
/// served, over the shared spec. Artifact-free — this is what the
/// concurrency tests and the HTTP front-end run on.
///
/// At every batch boundary the worker compares its local epoch with the
/// swap cell; on a mismatch it re-snapshots the version table (dropping
/// executors of retired versions) before executing, so a single batch
/// never mixes plan versions. Executors for versions beyond the active
/// one (canary / shadow candidates) build lazily on first use and stay
/// cached until the version is retired.
#[allow(clippy::too_many_arguments)]
fn emulator_worker(
    spec: &EmulatorSpec,
    swap: &SwapState,
    queue: &SharedQueue,
    max_wait: Duration,
    worker: usize,
    cell: &StatsCell,
    tracer: &TraceRecorder,
    profiler: &Arc<LayerProfiler>,
    ready: &mpsc::Sender<Result<(usize, usize)>>,
) {
    let per: usize = spec.model.input_shape.iter().product();
    let mut local_epoch = swap.epoch.load(Ordering::Acquire);
    let (mut entries, mut active) = {
        let t = swap.table.lock().expect("swap state poisoned");
        (t.entries.clone(), t.active)
    };
    let mut execs: BTreeMap<u64, Executor> = BTreeMap::new();
    // Build the active version's executor up front: it validates the
    // backend before the pool reports ready.
    let setup = match entries.get(&active) {
        Some(vp) => emulator_executor(spec, vp, profiler),
        None => Err(anyhow::anyhow!("no active plan version")),
    };
    match setup {
        Ok(exec) => {
            execs.insert(active, exec);
            let _ = ready.send(Ok((spec.model.out_dim, per)));
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    }

    // Token-sequence models take rounded ids; anything else is rejected
    // per-request with a typed error (not a refused start).
    let dtype = spec.model.input_dtype.clone();
    let bs = spec.batch.max(1);
    let mut shape = vec![bs];
    shape.extend_from_slice(&spec.model.input_shape);
    batching_loop(queue, bs, per, max_wait, worker, cell, tracer, |version, flat| {
        // Batch boundary: adopt newly published table changes before
        // touching this group; executors of retired versions go with it.
        let cur = swap.epoch.load(Ordering::Acquire);
        if cur != local_epoch {
            let t = swap.table.lock().expect("swap state poisoned");
            entries = t.entries.clone();
            active = t.active;
            drop(t);
            execs.retain(|v, _| entries.contains_key(v));
            local_epoch = cur;
        }
        let v = version.unwrap_or(active);
        let Some(vp) = entries.get(&v) else {
            // Pinned to a version retired while the request queued.
            return Err(ServiceError::NoSuchVersion { version: v });
        };
        if let std::collections::btree_map::Entry::Vacant(slot) = execs.entry(v) {
            slot.insert(
                emulator_executor(spec, vp, profiler)
                    .map_err(|e| ServiceError::Backend(format!("{e:#}")))?,
            );
        }
        let exec = execs.get(&v).expect("executor cached above");
        let input = match dtype.as_str() {
            "f32" => Value::F(
                Tensor::from_vec(&shape, flat.to_vec())
                    .map_err(|e| ServiceError::Backend(format!("{e:#}")))?,
            ),
            "i32" => Value::I(
                TensorI32::from_vec(&shape, flat.iter().map(|v| v.round() as i32).collect())
                    .map_err(|e| ServiceError::Backend(format!("{e:#}")))?,
            ),
            other => return Err(ServiceError::UnsupportedDtype(other.to_string())),
        };
        exec.forward(input)
            .map(|out| (out.data, vp.gen_no, vp.version))
            .map_err(|e| ServiceError::Backend(format!("{e:#}")))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_bucket_edges() {
        assert_eq!(LatencyHist::bucket_of(Duration::from_nanos(300)), 0);
        assert_eq!(LatencyHist::bucket_of(Duration::from_micros(1)), 1);
        assert_eq!(LatencyHist::bucket_of(Duration::from_micros(2)), 2);
        assert_eq!(LatencyHist::bucket_of(Duration::from_micros(3)), 2);
        assert_eq!(LatencyHist::bucket_of(Duration::from_micros(4)), 3);
        assert_eq!(LatencyHist::bucket_of(Duration::from_millis(1)), 10);
        // The top bucket is open-ended: nothing can index past it.
        assert_eq!(
            LatencyHist::bucket_of(Duration::from_secs(3600)),
            LAT_BUCKETS - 1
        );
    }

    #[test]
    fn hist_percentiles() {
        let mut h = LatencyHist::default();
        assert_eq!(h.percentile_us(0.99), 0, "empty hist reports 0");
        // 90 samples at ~1 ms (bucket 10), 10 at ~32 ms (bucket 15).
        h.buckets[10] = 90;
        h.buckets[15] = 10;
        assert_eq!(h.count(), 100);
        assert_eq!(h.percentile_us(0.50), LatencyHist::upper_edge_us(10));
        assert_eq!(h.percentile_us(0.90), LatencyHist::upper_edge_us(10));
        assert_eq!(h.percentile_us(0.95), LatencyHist::upper_edge_us(15));
        assert_eq!(h.percentile_us(0.99), LatencyHist::upper_edge_us(15));
        let mut other = LatencyHist::default();
        other.buckets[15] = 5;
        h.merge(&other);
        assert_eq!(h.count(), 105);
    }

    #[test]
    fn hist_bucket_of_is_monotone() {
        // bucket_of must never decrease as the duration grows, across
        // nine decades of µs values (incl. the boundaries 2^k ± 1).
        let mut probes: Vec<u64> = vec![0];
        for k in 0..40u32 {
            let edge = 1u64 << k;
            probes.extend_from_slice(&[edge.saturating_sub(1), edge, edge + 1]);
        }
        probes.sort_unstable();
        let mut prev = 0usize;
        for us in probes {
            let b = LatencyHist::bucket_of(Duration::from_micros(us));
            assert!(b >= prev, "bucket_of({us}µs)={b} < previous {prev}");
            assert!(b < LAT_BUCKETS);
            prev = b;
        }
    }

    #[test]
    fn hist_bucket_brackets_value() {
        // Every non-saturating sample must satisfy the documented bucket
        // semantics: value ≤ upper edge, and ≥ half the edge for i ≥ 1.
        let mut rng = 0x2545F4914F6CDD1Du64;
        for _ in 0..2000 {
            rng ^= rng << 13;
            rng ^= rng >> 7;
            rng ^= rng << 17;
            let us = rng % (1u64 << 26); // keep below the open top bucket
            let i = LatencyHist::bucket_of(Duration::from_micros(us));
            let upper = LatencyHist::upper_edge_us(i);
            assert!(us <= upper, "{us}µs above edge {upper} of bucket {i}");
            if i >= 1 {
                assert!(
                    us >= upper / 2,
                    "{us}µs below half-edge {} of bucket {i}",
                    upper / 2
                );
            }
        }
    }

    #[test]
    fn hist_merge_is_associative_and_commutative() {
        let mk = |seed: u64| {
            let mut h = LatencyHist::default();
            let mut rng = seed;
            for _ in 0..64 {
                rng ^= rng << 13;
                rng ^= rng >> 7;
                rng ^= rng << 17;
                h.buckets[(rng % LAT_BUCKETS as u64) as usize] += rng % 17;
            }
            h
        };
        let (a, b, c) = (mk(1), mk(2), mk(3));
        // (a ∪ b) ∪ c == a ∪ (b ∪ c)
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right, "merge must be associative");
        // a ∪ b == b ∪ a
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge must be commutative");
    }

    #[test]
    fn hist_percentile_within_one_bucket_of_exact() {
        // Synthetic sample set with a known exact percentile: the log2
        // histogram's estimate (the bucket's upper edge) must stay
        // within one bucket of it — i.e. exact ∈ [estimate/2, estimate]
        // for values ≥ 1µs.
        let mut samples: Vec<u64> = Vec::new();
        let mut rng = 0x9E3779B97F4A7C15u64;
        for _ in 0..5000 {
            rng ^= rng << 13;
            rng ^= rng >> 7;
            rng ^= rng << 17;
            samples.push(1 + rng % 1_000_000); // 1µs .. 1s
        }
        let mut h = LatencyHist::default();
        for &s in &samples {
            h.buckets[LatencyHist::bucket_of(Duration::from_micros(s))] += 1;
        }
        samples.sort_unstable();
        for &p in &[0.5, 0.9, 0.95, 0.99] {
            let rank = ((p * samples.len() as f64).ceil() as usize).max(1) - 1;
            let exact = samples[rank];
            let est = h.percentile_us(p);
            assert!(
                exact <= est && exact >= est / 2,
                "p{p}: exact {exact}µs outside [{}, {est}]µs",
                est / 2
            );
        }
    }

    #[test]
    fn stats_merge_includes_hists() {
        let mk = |requests: usize, bucket: usize, n: u64| {
            let mut queue_hist = LatencyHist::default();
            queue_hist.buckets[bucket] = n;
            EngineStats {
                requests,
                queue_hist,
                ..EngineStats::default()
            }
        };
        let mut a = mk(3, 2, 3);
        let b = mk(4, 4, 4);
        a.merge(&b);
        assert_eq!(a.requests, 7);
        assert_eq!(a.queue_hist.count(), 7);
    }
}
