//! Request-level inference engine: dynamic batching in front of the
//! fixed-batch AOT executables.
//!
//! The AOT artifacts are lowered at a static batch size; user-facing
//! inference arrives one sample at a time. The engine queues requests,
//! forms a batch when either the batch fills or `max_wait` expires
//! (classic dynamic batching), pads short batches by repeating the last
//! sample, executes, and fans responses back out. The PJRT client is not
//! `Send`, so the worker thread owns its *own* Runtime — requests and
//! responses cross threads, the runtime never does.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::ops::{self, InferVariant, ModelState};
use crate::runtime::Runtime;

/// One inference request: a flat f32 sample (image/latent).
struct Request {
    x: Vec<f32>,
    resp: mpsc::Sender<Result<Vec<f32>>>,
}

enum Msg {
    Req(Request),
    Shutdown,
}

/// Engine statistics (updated by the worker, fetched at shutdown).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests: usize,
    pub batches: usize,
    pub padded_slots: usize,
    pub busy: Duration,
}

/// Configuration for [`InferenceEngine`].
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts: PathBuf,
    pub model: String,
    pub variant: InferVariant,
    /// ACU name when `variant == ApproxLut`.
    pub acu: Option<String>,
    /// Max time to hold a partial batch before flushing.
    pub max_wait: Duration,
}

/// Handle to the batching worker.
pub struct InferenceEngine {
    tx: mpsc::Sender<Msg>,
    worker: Option<std::thread::JoinHandle<Result<EngineStats>>>,
    out_dim: usize,
}

impl InferenceEngine {
    /// Start the worker (compiles the executable before accepting work).
    pub fn start(cfg: EngineConfig) -> Result<InferenceEngine> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize>>();
        let worker = std::thread::spawn(move || worker_loop(cfg, rx, ready_tx));
        let out_dim = ready_rx
            .recv()
            .context("engine worker died before ready")??;
        Ok(InferenceEngine {
            tx,
            worker: Some(worker),
            out_dim,
        })
    }

    /// Output dimension per sample.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Submit one sample; returns a receiver for its output row.
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let (resp, rx) = mpsc::channel();
        self.tx
            .send(Msg::Req(Request { x, resp }))
            .context("engine is down")?;
        Ok(rx)
    }

    /// Blocking convenience wrapper around [`submit`].
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(x)?.recv().context("engine dropped request")?
    }

    /// Stop the worker and fetch stats.
    pub fn shutdown(mut self) -> Result<EngineStats> {
        let _ = self.tx.send(Msg::Shutdown);
        let h = self.worker.take().expect("shutdown twice");
        h.join().map_err(|_| anyhow::anyhow!("worker panicked"))?
    }
}

impl Drop for InferenceEngine {
    fn drop(&mut self) {
        if self.worker.is_some() {
            let _ = self.tx.send(Msg::Shutdown);
            if let Some(h) = self.worker.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_loop(
    cfg: EngineConfig,
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<usize>>,
) -> Result<EngineStats> {
    // The runtime lives entirely on this thread (PJRT is not Send).
    let setup = (|| -> Result<(Runtime, ModelState, Option<xla::Literal>, usize)> {
        let mut rt = Runtime::open(&cfg.artifacts)?;
        let mut st = ModelState::load_best(&rt, &cfg.model)?;
        let lut_lit = match (&cfg.variant, &cfg.acu) {
            (InferVariant::ApproxLut, Some(acu)) => Some(ops::load_lut_lit(&rt, acu)?),
            (InferVariant::ApproxLut, None) => {
                anyhow::bail!("ApproxLut engine needs an ACU name")
            }
            _ => None,
        };
        if cfg.variant != InferVariant::Fp32 {
            // Engine-side quick calibration on the model's dataset.
            let ds = crate::data::load(&st.model.dataset, &crate::data::Sizes::small());
            ops::calibrate(
                &mut rt,
                &mut st,
                &ds,
                2,
                crate::quant::calib::CalibratorKind::Percentile,
                0.999,
            )?;
        }
        rt.prepare(&cfg.model, cfg.variant.artifact())?;
        let out_dim = st.model.out_dim;
        Ok((rt, st, lut_lit, out_dim))
    })();

    let (mut rt, st, lut_lit, out_dim) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(v.3));
            (v.0, v.1, v.2, v.3)
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return Ok(EngineStats::default());
        }
    };
    let _ = out_dim;

    let bs = rt.manifest.batch;
    let per: usize = st.model.input_shape.iter().product();
    let mut stats = EngineStats::default();
    let mut pending: Vec<Request> = Vec::with_capacity(bs);

    // A Shutdown received while gathering a batch must still flush that
    // batch *and then stop*: without the flag the inner `break` only ended
    // the gather loop and the worker re-blocked on `rx.recv()` forever,
    // deadlocking `shutdown()`'s join.
    let mut shutting_down = false;

    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        pending.push(first);
        let deadline = Instant::now() + cfg.max_wait;
        // Gather until full, deadline, or shutdown (flush first).
        while pending.len() < bs {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }

        // Assemble the padded batch.
        let t0 = Instant::now();
        let mut flat = Vec::with_capacity(bs * per);
        for r in &pending {
            flat.extend_from_slice(&r.x);
        }
        let real = pending.len();
        for _ in real..bs {
            let last = &pending[real - 1].x;
            flat.extend_from_slice(last);
        }
        stats.padded_slots += bs - real;
        let mut shape = vec![bs];
        shape.extend_from_slice(&st.model.input_shape);

        let result = crate::runtime::lit_f32(&shape, &flat).and_then(|x| {
            ops::infer_batch(&mut rt, &st, cfg.variant, &x, lut_lit.as_ref())
        });
        stats.busy += t0.elapsed();
        stats.batches += 1;
        stats.requests += real;

        match result {
            Ok(out) => {
                let row = out.len() / bs;
                for (i, r) in pending.drain(..).enumerate() {
                    let _ = r.resp.send(Ok(out[i * row..(i + 1) * row].to_vec()));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in pending.drain(..) {
                    let _ = r.resp.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
        if shutting_down {
            break;
        }
    }
    Ok(stats)
}
