//! Request-level inference engine: a pool of dynamic-batching workers in
//! front of a shared bounded request queue.
//!
//! User-facing inference arrives one sample at a time; execution wants
//! fixed-size batches. The engine queues requests in a *bounded* queue
//! (submitters block when it fills — backpressure instead of unbounded
//! memory growth) and runs `workers` batching loops against it. Each
//! worker owns its backend outright — a PJRT [`Runtime`] (not `Send`, so
//! it can never be shared) or a Rust [`Executor`] with its own scratch
//! arena — forms a batch when either the batch fills or `max_wait`
//! expires (classic dynamic batching), pads short batches by repeating
//! the last sample, executes, and fans responses back out.
//!
//! With `workers == 1` the batching semantics are exactly the old
//! single-worker engine's: one blocking gather loop, same padding, same
//! flush-on-shutdown. More workers add throughput, not new semantics —
//! requests and responses cross threads, backends never do.
//!
//! Shutdown drains: `shutdown()` closes the queue (new submits fail),
//! workers keep popping until the queue is empty, flush their final
//! partial batches, and report per-worker [`EngineStats`] which are
//! aggregated into [`PoolStats`].

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::ops::{self, InferVariant, ModelState};
use crate::emulator::{Executor, PreparedWeights, ScratchArena, Style, Value};
use crate::graph::{ExecutionPlan, Model};
use crate::lut::LutRegistry;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// One inference request: a flat f32 sample (image/latent).
struct Request {
    x: Vec<f32>,
    resp: mpsc::Sender<Result<Vec<f32>>>,
    /// When the request entered the queue (for `queue_wait`).
    enqueued: Instant,
}

/// Per-worker (and aggregated) engine statistics.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub requests: usize,
    pub batches: usize,
    pub padded_slots: usize,
    /// Total time requests spent queued before a worker picked them up.
    pub queue_wait: Duration,
    /// Time spent assembling + executing batches.
    pub busy: Duration,
}

impl EngineStats {
    fn merge(&mut self, other: &EngineStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.padded_slots += other.padded_slots;
        self.queue_wait += other.queue_wait;
        self.busy += other.busy;
    }
}

/// Aggregate + per-worker stats returned by [`InferenceEngine::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// Sums over all workers.
    pub total: EngineStats,
    /// One entry per pool worker, in spawn order.
    pub per_worker: Vec<EngineStats>,
}

/// What each pool worker runs batches on. PJRT state is not `Send`, so a
/// worker *constructs* its backend on its own thread from this spec.
#[derive(Clone)]
pub enum BackendSpec {
    /// The AOT executables through a per-worker PJRT [`Runtime`].
    Pjrt {
        artifacts: PathBuf,
        model: String,
        variant: InferVariant,
        /// ACU name when `variant == ApproxLut`.
        acu: Option<String>,
    },
    /// The in-process Rust emulator (artifact-free): every worker owns its
    /// own [`Executor`] + scratch arena over this shared spec.
    Emulator(Arc<EmulatorSpec>),
}

/// Spec for [`BackendSpec::Emulator`] workers. Shared read-only (`Arc`);
/// the pool quantizes the weights once at [`InferenceEngine::start`] and
/// every worker adopts the shared [`PreparedWeights`].
pub struct EmulatorSpec {
    pub model: Model,
    pub params: Vec<Tensor>,
    pub plan: ExecutionPlan,
    pub act_scales: Vec<f32>,
    pub luts: LutRegistry,
    /// Engine batch size (the PJRT backend takes it from the manifest).
    pub batch: usize,
    /// GEMM threads inside one worker's forward pass.
    pub gemm_threads: usize,
}

/// Configuration for [`InferenceEngine`].
pub struct EngineConfig {
    pub backend: BackendSpec,
    /// Max time a worker holds a partial batch before flushing.
    pub max_wait: Duration,
    /// Pool size. Default [`default_threads`](crate::util::threadpool::default_threads)
    /// (`ADAPT_THREADS` env); 1 reproduces the old single-worker engine.
    pub workers: usize,
    /// Bounded request-queue depth; [`InferenceEngine::submit`] blocks
    /// while the queue is full (backpressure).
    pub queue_depth: usize,
}

/// Default bounded queue depth (requests, not batches).
pub const DEFAULT_QUEUE_DEPTH: usize = 256;

impl EngineConfig {
    /// PJRT-backed engine with default pool sizing.
    pub fn pjrt(
        artifacts: PathBuf,
        model: impl Into<String>,
        variant: InferVariant,
        acu: Option<String>,
    ) -> EngineConfig {
        EngineConfig {
            backend: BackendSpec::Pjrt {
                artifacts,
                model: model.into(),
                variant,
                acu,
            },
            max_wait: Duration::from_millis(20),
            workers: crate::util::threadpool::default_threads(),
            queue_depth: DEFAULT_QUEUE_DEPTH,
        }
    }

    /// Emulator-backed engine with default pool sizing.
    pub fn emulator(spec: EmulatorSpec) -> EngineConfig {
        EngineConfig {
            backend: BackendSpec::Emulator(Arc::new(spec)),
            max_wait: Duration::from_millis(20),
            workers: crate::util::threadpool::default_threads(),
            queue_depth: DEFAULT_QUEUE_DEPTH,
        }
    }
}

// ---------------------------------------------------------------------------
// Shared bounded request queue
// ---------------------------------------------------------------------------

struct QueueState {
    items: VecDeque<Request>,
    closed: bool,
}

/// MPMC bounded queue: submitters block on `not_full` (backpressure),
/// workers block on `not_empty`. Closing wakes everyone; workers drain
/// whatever is left before exiting.
struct SharedQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

/// Outcome of a deadline-bounded pop (the batch-gathering wait).
enum Popped {
    Item(Request),
    TimedOut,
    /// Queue closed and fully drained.
    Drained,
}

impl SharedQueue {
    fn new(cap: usize) -> SharedQueue {
        SharedQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push; applies backpressure while full. Errors once closed.
    fn push(&self, req: Request) -> Result<()> {
        let mut st = self.state.lock().expect("engine queue poisoned");
        loop {
            if st.closed {
                anyhow::bail!("engine is shut down");
            }
            if st.items.len() < self.cap {
                break;
            }
            st = self.not_full.wait(st).expect("engine queue poisoned");
        }
        st.items.push_back(req);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop for the first request of a batch. `None` only when the
    /// queue is closed *and* drained.
    fn pop_blocking(&self) -> Option<Request> {
        let mut st = self.state.lock().expect("engine queue poisoned");
        loop {
            if let Some(r) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(r);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).expect("engine queue poisoned");
        }
    }

    /// Pop one more request for the current batch, waiting at most until
    /// `deadline`.
    fn pop_until(&self, deadline: Instant) -> Popped {
        let mut st = self.state.lock().expect("engine queue poisoned");
        loop {
            if let Some(r) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Popped::Item(r);
            }
            if st.closed {
                return Popped::Drained;
            }
            let now = Instant::now();
            if now >= deadline {
                return Popped::TimedOut;
            }
            let (guard, _timeout) = self
                .not_empty
                .wait_timeout(st, deadline - now)
                .expect("engine queue poisoned");
            st = guard;
        }
    }

    fn close(&self) {
        let mut st = self.state.lock().expect("engine queue poisoned");
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Engine pool
// ---------------------------------------------------------------------------

/// Handle to the worker pool.
pub struct InferenceEngine {
    queue: Arc<SharedQueue>,
    workers: Vec<std::thread::JoinHandle<EngineStats>>,
    out_dim: usize,
}

impl InferenceEngine {
    /// Start the pool. Every worker compiles/prepares its backend before
    /// the call returns; the first setup failure aborts the whole pool.
    ///
    /// Emulator backends quantize the model's weights exactly **once**
    /// here ([`Executor::prepare_weights`]); every worker adopts the same
    /// shared tables behind an `Arc` instead of re-quantizing its own
    /// copy — the shared quantized-weight cache for pool workers.
    pub fn start(cfg: EngineConfig) -> Result<InferenceEngine> {
        let n_workers = cfg.workers.max(1);
        let queue = Arc::new(SharedQueue::new(cfg.queue_depth));
        // Shared quantized-weight cache (emulator backends only). Failing
        // here (e.g. an unknown ACU in the plan) aborts the start just
        // like a per-worker setup failure used to.
        let emu_prepared = match &cfg.backend {
            BackendSpec::Emulator(spec) => Some(Executor::prepare_weights(
                &spec.model,
                &spec.params,
                &spec.plan,
                &spec.luts,
            )?),
            _ => None,
        };
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize>>();
        let mut workers = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            let queue = Arc::clone(&queue);
            let ready = ready_tx.clone();
            let backend = cfg.backend.clone();
            let prepared = emu_prepared.clone();
            let max_wait = cfg.max_wait;
            let handle = std::thread::Builder::new()
                .name(format!("adapt-engine-{wi}"))
                .spawn(move || match backend {
                    BackendSpec::Pjrt {
                        artifacts,
                        model,
                        variant,
                        acu,
                    } => pjrt_worker(&artifacts, &model, variant, acu, &queue, max_wait, &ready),
                    BackendSpec::Emulator(spec) => {
                        let prepared = prepared.expect("emulator backend prepared above");
                        emulator_worker(&spec, prepared, &queue, max_wait, &ready)
                    }
                })
                .context("spawning engine worker")?;
            workers.push(handle);
        }
        drop(ready_tx);

        let mut out_dim = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(d)) => out_dim = d,
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!("engine worker died before ready"));
                    }
                }
            }
        }
        if let Some(e) = first_err {
            queue.close();
            for h in workers {
                let _ = h.join();
            }
            return Err(e);
        }
        Ok(InferenceEngine {
            queue,
            workers,
            out_dim,
        })
    }

    /// Output dimension per sample.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Number of pool workers.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit one sample; returns a receiver for its output row. Blocks
    /// while the request queue is full (backpressure).
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let (resp, rx) = mpsc::channel();
        self.queue.push(Request {
            x,
            resp,
            enqueued: Instant::now(),
        })?;
        Ok(rx)
    }

    /// Blocking convenience wrapper around [`submit`](Self::submit).
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.submit(x)?.recv().context("engine dropped request")?
    }

    /// Stop the pool: close the queue, let every worker drain + flush, and
    /// aggregate their stats.
    pub fn shutdown(mut self) -> Result<PoolStats> {
        self.queue.close();
        let mut per_worker = Vec::with_capacity(self.workers.len());
        for h in self.workers.drain(..) {
            let s = h
                .join()
                .map_err(|_| anyhow::anyhow!("engine worker panicked"))?;
            per_worker.push(s);
        }
        let mut total = EngineStats::default();
        for s in &per_worker {
            total.merge(s);
        }
        Ok(PoolStats { total, per_worker })
    }
}

impl Drop for InferenceEngine {
    fn drop(&mut self) {
        if !self.workers.is_empty() {
            self.queue.close();
            for h in self.workers.drain(..) {
                let _ = h.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

/// The shared dynamic-batching loop: gather up to `bs` requests (first one
/// blocking, the rest until `max_wait`), pad, run `infer`, fan out.
/// `per` is the flat per-sample input length.
fn batching_loop<F>(
    queue: &SharedQueue,
    bs: usize,
    per: usize,
    max_wait: Duration,
    mut infer: F,
) -> EngineStats
where
    F: FnMut(&[f32]) -> Result<Vec<f32>>,
{
    let mut stats = EngineStats::default();
    let mut pending: Vec<Request> = Vec::with_capacity(bs);
    let mut flat: Vec<f32> = Vec::with_capacity(bs * per);
    // A malformed request must never take down the worker (or the rest of
    // its batch): answer it with an error and keep it out of the batch.
    let admit = |r: Request, pending: &mut Vec<Request>, stats: &mut EngineStats| {
        stats.queue_wait += r.enqueued.elapsed();
        if r.x.len() == per {
            pending.push(r);
        } else {
            let _ = r.resp.send(Err(anyhow::anyhow!(
                "request input length {} != expected {per}",
                r.x.len()
            )));
        }
    };
    loop {
        // Block for the first request of a batch (or drained shutdown).
        let Some(first) = queue.pop_blocking() else {
            break;
        };
        admit(first, &mut pending, &mut stats);
        let deadline = Instant::now() + max_wait;
        // A close() during the gather must still flush this batch *and
        // then* let the outer loop observe the drained queue and stop.
        let mut drained = false;
        while pending.len() < bs {
            match queue.pop_until(deadline) {
                Popped::Item(r) => admit(r, &mut pending, &mut stats),
                Popped::TimedOut => break,
                Popped::Drained => {
                    drained = true;
                    break;
                }
            }
        }
        if pending.is_empty() {
            // Every gathered request was malformed; nothing to execute.
            if drained {
                break;
            }
            continue;
        }

        // Assemble the padded batch.
        let t0 = Instant::now();
        flat.clear();
        for r in &pending {
            flat.extend_from_slice(&r.x);
        }
        let real = pending.len();
        for _ in real..bs {
            let last_start = (real - 1) * per;
            flat.extend_from_within(last_start..last_start + per);
        }
        stats.padded_slots += bs - real;

        let result = infer(&flat);
        stats.busy += t0.elapsed();
        stats.batches += 1;
        stats.requests += real;

        match result {
            Ok(out) => {
                let row = out.len() / bs;
                for (i, r) in pending.drain(..).enumerate() {
                    let _ = r.resp.send(Ok(out[i * row..(i + 1) * row].to_vec()));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in pending.drain(..) {
                    let _ = r.resp.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
        if drained {
            break;
        }
    }
    stats
}

/// PJRT-backed worker: owns its own `Runtime` (PJRT is not `Send`),
/// compiles the executable, then serves the shared queue.
fn pjrt_worker(
    artifacts: &std::path::Path,
    model: &str,
    variant: InferVariant,
    acu: Option<String>,
    queue: &SharedQueue,
    max_wait: Duration,
    ready: &mpsc::Sender<Result<usize>>,
) -> EngineStats {
    let setup = (|| -> Result<(Runtime, ModelState, Option<xla::Literal>)> {
        let mut rt = Runtime::open(artifacts)?;
        let mut st = ModelState::load_best(&rt, model)?;
        let lut_lit = match (variant, &acu) {
            (InferVariant::ApproxLut, Some(acu)) => Some(ops::load_lut_lit(&rt, acu)?),
            (InferVariant::ApproxLut, None) => {
                anyhow::bail!("ApproxLut engine needs an ACU name")
            }
            _ => None,
        };
        if variant != InferVariant::Fp32 {
            // Engine-side quick calibration on the model's dataset.
            let ds = crate::data::load(&st.model.dataset, &crate::data::Sizes::small());
            ops::calibrate(
                &mut rt,
                &mut st,
                &ds,
                2,
                crate::quant::calib::CalibratorKind::Percentile,
                0.999,
            )?;
        }
        rt.prepare(model, variant.artifact())?;
        Ok((rt, st, lut_lit))
    })();

    let (mut rt, st, lut_lit) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(v.1.model.out_dim));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return EngineStats::default();
        }
    };

    let bs = rt.manifest.batch;
    let per: usize = st.model.input_shape.iter().product();
    let mut shape = vec![bs];
    shape.extend_from_slice(&st.model.input_shape);
    batching_loop(queue, bs, per, max_wait, |flat| {
        let x = crate::runtime::lit_f32(&shape, flat)?;
        ops::infer_batch(&mut rt, &st, variant, &x, lut_lit.as_ref())
    })
}

fn emulator_setup(spec: &EmulatorSpec, prepared: PreparedWeights) -> Result<Executor<'_>> {
    anyhow::ensure!(
        spec.model.input_dtype == "f32",
        "emulator engine serves f32-input models (got {})",
        spec.model.input_dtype
    );
    Executor::with_prepared(
        &spec.model,
        spec.params.clone(),
        spec.plan.clone(),
        spec.act_scales.clone(),
        Style::Optimized {
            threads: spec.gemm_threads.max(1),
        },
        prepared,
        ScratchArena::new(),
    )
}

/// Emulator-backed worker: adopts the pool's shared quantized weights
/// (one `Arc` clone, no re-quantization) and owns its own scratch arena
/// over the shared spec, then serves the queue. Artifact-free — this is
/// what the concurrency tests run on.
fn emulator_worker(
    spec: &EmulatorSpec,
    prepared: PreparedWeights,
    queue: &SharedQueue,
    max_wait: Duration,
    ready: &mpsc::Sender<Result<usize>>,
) -> EngineStats {
    let exec = match emulator_setup(spec, prepared) {
        Ok(exec) => {
            let _ = ready.send(Ok(spec.model.out_dim));
            exec
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return EngineStats::default();
        }
    };

    let bs = spec.batch.max(1);
    let per: usize = spec.model.input_shape.iter().product();
    let mut shape = vec![bs];
    shape.extend_from_slice(&spec.model.input_shape);
    batching_loop(queue, bs, per, max_wait, |flat| {
        let x = Tensor::from_vec(&shape, flat.to_vec())?;
        Ok(exec.forward(Value::F(x))?.data)
    })
}
