//! Primitive coordinator operations over the AOT executables.
//!
//! The whole Fig.-1 flow lives here: fp32 pre-training (the Rust
//! coordinator *is* the training loop — python only lowered the step),
//! post-training calibration via the `acts` taps, approximate inference
//! through the LUT / functional variants, and approximation-aware
//! retraining (QAT).

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::data::{Dataset, Split};
use crate::graph::{ExecutionPlan, Model};
use crate::lut::{Lut, LutRegistry};
use crate::metrics;
use crate::quant::calib::{Calibrator, CalibratorKind, HistogramCalibrator};
use crate::runtime::{lit_f32, lit_i32, lit_scalar_f32, to_vec_f32, weights, Runtime};
use crate::tensor::Tensor;

/// Mutable model state owned by the coordinator: current parameters (as
/// literals, fed straight back into the next executable call) + scales.
pub struct ModelState {
    pub model: Model,
    pub params: Vec<xla::Literal>,
    pub act_scales: Option<Vec<f32>>,
}

impl ModelState {
    /// Load state from a weights blob (initial or trained snapshot).
    pub fn load(rt: &Runtime, name: &str, weights_path: &Path) -> Result<ModelState> {
        let model = rt.manifest.model(name)?.clone();
        let tensors = weights::load_params(&model, weights_path)?;
        let params = tensors
            .iter()
            .map(|t| lit_f32(&t.shape, &t.data))
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelState {
            model,
            params,
            act_scales: None,
        })
    }

    /// Load from initial weights, preferring a trained snapshot if present.
    pub fn load_best(rt: &Runtime, name: &str) -> Result<ModelState> {
        let model = rt.manifest.model(name)?;
        let trained = weights::trained_path(&rt.manifest.root, model);
        let path = if trained.exists() {
            trained
        } else {
            weights::initial_path(&rt.manifest.root, model)
        };
        Self::load(rt, name, &path)
    }

    /// Export current params to CPU tensors (for the Rust emulators or a
    /// weights snapshot).
    pub fn params_tensors(&self) -> Result<Vec<Tensor>> {
        self.model
            .params
            .iter()
            .zip(&self.params)
            .map(|(spec, lit)| Tensor::from_vec(&spec.shape, to_vec_f32(lit)?))
            .collect()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        weights::save_params(&self.params_tensors()?, path)
    }

    /// Replace the state's parameters from CPU tensors (inverse of
    /// [`params_tensors`](Self::params_tensors) — how the emulator
    /// trainer hands updated weights back to the literal-based flow).
    pub fn set_params_tensors(&mut self, tensors: &[Tensor]) -> Result<()> {
        if tensors.len() != self.model.params.len() {
            bail!(
                "model {} expects {} params, got {}",
                self.model.name,
                self.model.params.len(),
                tensors.len()
            );
        }
        self.params = tensors
            .iter()
            .map(|t| lit_f32(&t.shape, &t.data))
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }

    /// Activation scales as a literal, rescaled from the calibrated 8-bit
    /// scales to the requested bitwidth (calib_max / qmax(bits)).
    fn scales_lit(&self, bits: u32) -> Result<xla::Literal> {
        let s = self
            .act_scales
            .as_ref()
            .context("model not calibrated (run calibrate first)")?;
        let s = rescale_for_bits(s, bits);
        lit_f32(&[s.len()], &s)
    }
}

/// Load an ACU LUT artifact as a PJRT literal (the XLA approx path's
/// operand). The Rust engines don't take this — they resolve shared
/// in-memory tables through [`crate::lut::LutRegistry`] instead, so the
/// artifact is read at most once per consumer.
pub fn load_lut_lit(rt: &Runtime, acu: &str) -> Result<xla::Literal> {
    let path = rt.manifest.lut_path(acu)?;
    let lut = Lut::load(&path)?;
    lit_i32(&[lut.n, lut.n], lut.data())
}

/// Build the input literal for one batch of a split.
pub fn batch_input(model: &Model, split: &Split, bi: usize, bs: usize) -> Result<xla::Literal> {
    let mut shape = vec![bs];
    shape.extend_from_slice(&model.input_shape);
    if model.input_dtype == "i32" {
        lit_i32(&shape, &split.batch_i(bi, bs))
    } else {
        lit_f32(&shape, &split.batch_f(bi, bs))
    }
}

/// Input literal for one already-flattened f32 batch — the serving path's
/// counterpart of [`batch_input`]. Dtype-aware: i32-input models (token
/// sequences) take the values as rounded ids, the same dequant-free route
/// the eval harness uses, so the engine pool serves them too instead of
/// bailing at startup.
pub fn flat_batch_input(model: &Model, bs: usize, flat: &[f32]) -> Result<xla::Literal> {
    let mut shape = vec![bs];
    shape.extend_from_slice(&model.input_shape);
    if model.input_dtype == "i32" {
        let ids: Vec<i32> = flat.iter().map(|v| v.round() as i32).collect();
        lit_i32(&shape, &ids)
    } else {
        lit_f32(&shape, flat)
    }
}

/// Inference variants (map to artifact names).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InferVariant {
    Fp32,
    /// 8-bit LUT path; the ACU is whatever LUT literal you pass.
    ApproxLut,
    /// 12-bit exact-quantized (functional k = 0).
    Quant12,
    /// 12-bit functional ACU (mul12s_2km_like).
    Approx12,
}

impl InferVariant {
    pub fn artifact(&self) -> &'static str {
        match self {
            InferVariant::Fp32 => "fp32_infer",
            InferVariant::ApproxLut => "approx_infer",
            InferVariant::Quant12 => "quant12_infer",
            InferVariant::Approx12 => "approx12_infer",
        }
    }
}

/// Run one inference batch; returns the flat output.
pub fn infer_batch(
    rt: &mut Runtime,
    st: &ModelState,
    variant: InferVariant,
    x: &xla::Literal,
    lut: Option<&xla::Literal>,
) -> Result<Vec<f32>> {
    let mut inputs: Vec<&xla::Literal> = st.params.iter().collect();
    let scales_lit;
    match variant {
        InferVariant::Fp32 => {}
        InferVariant::ApproxLut => {
            scales_lit = st.scales_lit(8)?;
            inputs.push(&scales_lit);
        }
        InferVariant::Quant12 | InferVariant::Approx12 => {
            scales_lit = st.scales_lit(12)?;
            inputs.push(&scales_lit);
        }
    }
    inputs.push(x);
    if variant == InferVariant::ApproxLut {
        inputs.push(lut.context("LUT variant needs a LUT literal")?);
    }
    let out = rt.run(&st.model.name, variant.artifact(), &inputs)?;
    to_vec_f32(&out[0])
}

/// Evaluation outcome for one (model, variant) pair.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub accuracy: f64,
    pub wall: Duration,
    pub batches: usize,
    pub samples: usize,
}

/// Evaluate a variant over the eval split.
pub fn evaluate(
    rt: &mut Runtime,
    st: &ModelState,
    variant: InferVariant,
    ds: &Dataset,
    lut: Option<&xla::Literal>,
    max_batches: Option<usize>,
) -> Result<EvalResult> {
    let bs = rt.manifest.batch;
    let nb = ds
        .eval
        .n_batches(bs)
        .min(max_batches.unwrap_or(usize::MAX))
        .max(1);
    // Pre-compile outside the timed region (the paper's timings exclude
    // the one-off JIT/Ninja build as well).
    rt.prepare(&st.model.name, variant.artifact())?;
    let mut acc_sum = 0.0;
    let mut samples = 0usize;
    let t0 = Instant::now();
    for bi in 0..nb {
        let x = batch_input(&st.model, &ds.eval, bi, bs)?;
        let out = infer_batch(rt, st, variant, &x, lut)?;
        let labels = ds.eval.batch_labels(bi, bs);
        let target = if st.model.metric == "pixel" {
            ds.eval.batch_f(bi, bs)
        } else {
            vec![]
        };
        let out_dim_total = out.len() / bs;
        acc_sum += metrics::compute(
            &st.model.metric,
            &out,
            out_dim_total,
            &labels,
            &target,
        ) * bs as f64;
        samples += bs;
    }
    Ok(EvalResult {
        accuracy: acc_sum / samples as f64,
        wall: t0.elapsed(),
        batches: nb,
        samples,
    })
}

/// Training mode for `train`.
#[derive(Clone, Copy, Debug)]
pub enum TrainVariant {
    Fp32,
    /// QAT on the 8-bit LUT ACU.
    QatLut,
    /// QAT on the 12-bit functional ACU.
    Qat12,
}

impl TrainVariant {
    pub fn artifact(&self) -> &'static str {
        match self {
            TrainVariant::Fp32 => "fp32_train",
            TrainVariant::QatLut => "qat_train",
            TrainVariant::Qat12 => "qat12_train",
        }
    }
}

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub steps: usize,
    pub wall: Duration,
    pub first_loss: f32,
    pub last_loss: f32,
    pub losses: Vec<f32>,
}

/// Drive `steps` SGD-with-momentum steps through the AOT train-step
/// executable. Parameters and velocity buffers round-trip as literals —
/// outputs of step t are inputs of step t+1 with no host-side conversion.
pub fn train(
    rt: &mut Runtime,
    st: &mut ModelState,
    variant: TrainVariant,
    ds: &Dataset,
    steps: usize,
    lr: f32,
    lut: Option<&xla::Literal>,
    log_every: usize,
) -> Result<TrainResult> {
    let bs = rt.manifest.batch;
    let p = st.params.len();
    rt.prepare(&st.model.name, variant.artifact())?;
    let lr_lit = lit_scalar_f32(lr);
    // Momentum state: zero-initialized, same shapes as the params.
    let mut vels: Vec<xla::Literal> = st
        .model
        .params
        .iter()
        .map(|spec| lit_f32(&spec.shape, &vec![0.0f32; spec.numel()]))
        .collect::<Result<Vec<_>>>()?;
    let mut losses = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for step in 0..steps {
        let x = batch_input(&st.model, &ds.train, step, bs)?;
        let y = lit_i32(&[bs], &ds.train.batch_labels(step, bs))?;
        let scales_lit;
        let mut inputs: Vec<&xla::Literal> = st.params.iter().chain(vels.iter()).collect();
        match variant {
            TrainVariant::Fp32 => {
                inputs.push(&x);
                inputs.push(&y);
                inputs.push(&lr_lit);
            }
            TrainVariant::QatLut => {
                scales_lit = st.scales_lit(8)?;
                inputs.push(&scales_lit);
                inputs.push(&x);
                inputs.push(&y);
                inputs.push(&lr_lit);
                inputs.push(lut.context("QatLut needs a LUT literal")?);
            }
            TrainVariant::Qat12 => {
                scales_lit = st.scales_lit(12)?;
                inputs.push(&scales_lit);
                inputs.push(&x);
                inputs.push(&y);
                inputs.push(&lr_lit);
            }
        }
        let mut out = rt.run(&st.model.name, variant.artifact(), &inputs)?;
        if out.len() != 2 * p + 1 {
            bail!(
                "train step returned {} outputs, expected {}",
                out.len(),
                2 * p + 1
            );
        }
        let loss_lit = out.pop().unwrap();
        let loss = to_vec_f32(&loss_lit)?[0];
        if !loss.is_finite() {
            bail!("{} diverged at step {step} (loss {loss})", st.model.name);
        }
        losses.push(loss);
        vels = out.split_off(p);
        st.params = out;
        if log_every > 0 && (step % log_every == 0 || step + 1 == steps) {
            crate::obs::log::info(
                "train",
                "step",
                &[
                    ("model", st.model.name.clone()),
                    ("variant", variant.artifact().to_string()),
                    ("step", step.to_string()),
                    ("loss", format!("{loss:.4}")),
                ],
            );
        }
    }
    Ok(TrainResult {
        steps,
        wall: t0.elapsed(),
        first_loss: losses.first().copied().unwrap_or(f32::NAN),
        last_loss: losses.last().copied().unwrap_or(f32::NAN),
        losses,
    })
}

/// Emulator-native counterpart of [`train`] — the `TrainVariant`-parallel
/// entry: the same QAT semantics (approximate forward, STE backward,
/// SGD-with-momentum) driven by [`crate::trainer::fit`] on the Rust
/// engines over an arbitrary [`ExecutionPlan`] — heterogeneous mixed-ACU
/// plans included — with no PJRT executable in the loop. Parameters
/// round-trip through the state exactly like [`train`]'s literals do, so
/// Table-2 harnesses (`benches/table2_retrain.rs`) can A/B the two QAT
/// paths row for row.
#[allow(clippy::too_many_arguments)]
pub fn train_emulator(
    st: &mut ModelState,
    plan: &ExecutionPlan,
    luts: &LutRegistry,
    ds: &Dataset,
    epochs: usize,
    lr: f32,
    batch: usize,
    seed: u64,
    threads: usize,
) -> Result<TrainResult> {
    let scales = st
        .act_scales
        .clone()
        .context("model not calibrated (run calibrate first)")?;
    let params = st.params_tensors()?;
    let cfg = crate::trainer::TrainConfig {
        epochs,
        lr,
        momentum: 0.9,
        batch,
        seed,
        threads,
        max_batches: None,
        log_every: 0,
        approx_backward: None,
    };
    let fit = crate::trainer::fit(&st.model, params, plan, &scales, luts, &ds.train, &cfg)?;
    st.set_params_tensors(&fit.params)?;
    Ok(TrainResult {
        steps: fit.steps,
        wall: fit.wall,
        first_loss: fit.first_loss,
        last_loss: fit.last_loss,
        losses: fit.losses,
    })
}

/// Post-training calibration (§3.2.1): run the `acts` executable over
/// `batches` calibration batches, stream every tap into a per-scale
/// calibrator, and store the resulting scales on the state.
///
/// The paper's default is the 99.9 % percentile histogram over two batches.
pub fn calibrate(
    rt: &mut Runtime,
    st: &mut ModelState,
    ds: &Dataset,
    batches: usize,
    kind: CalibratorKind,
    percentile: f64,
) -> Result<Vec<f32>> {
    let bs = rt.manifest.batch;
    let n_scales = st.model.n_scales;
    let mut calibs: Vec<HistogramCalibrator> = (0..n_scales)
        .map(|_| HistogramCalibrator::new(kind).with_percentile(percentile))
        .collect();
    for bi in 0..batches.max(1) {
        let x = batch_input(&st.model, &ds.train, bi, bs)?;
        let mut inputs: Vec<&xla::Literal> = st.params.iter().collect();
        inputs.push(&x);
        let taps = rt.run(&st.model.name, "acts", &inputs)?;
        if taps.len() != n_scales {
            bail!("acts returned {} taps, expected {n_scales}", taps.len());
        }
        for (c, tap) in calibs.iter_mut().zip(&taps) {
            c.observe(&to_vec_f32(tap)?);
        }
    }
    let scales: Vec<f32> = calibs.iter().map(|c| c.scale(8)).collect();
    st.act_scales = Some(scales.clone());
    Ok(scales)
}

/// Calibrated scales, rescaled for a different bitwidth: the histogram
/// learned calib_max; scale_b = calib_max / qmax(b). Converting from the
/// 8-bit scales avoids a second calibration pass.
pub fn rescale_for_bits(scales8: &[f32], bits: u32) -> Vec<f32> {
    let q8 = crate::quant::qmax_for(8) as f32;
    let qb = crate::quant::qmax_for(bits) as f32;
    scales8.iter().map(|s| s * q8 / qb).collect()
}
