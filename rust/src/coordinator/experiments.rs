//! Experiment harnesses: regenerate every table of the paper's evaluation.
//!
//! * [`table1`] — model specs (params / MAC OPs) from the manifest.
//! * [`table2`] — quantization + retraining accuracy for the two Table-2
//!   ACU operating points across the five retrainable DNNs.
//! * [`table4`] — emulation wall-clock: native fp32 (XLA) vs baseline
//!   scalar LUT emulation (Rust naive) vs AdaPT (XLA approx path) vs the
//!   optimized Rust engine; speedups vs baseline.
//! * [`ablation`] — accuracy/MRE/power sweep over the whole ACU library
//!   (ALWANN-style operating-point exploration).
//! * [`layer_sensitivity`] — per-layer ACU sensitivity sweep + greedy
//!   mixed-ACU search under an accuracy budget, producing a heterogeneous
//!   [`ExecutionPlan`] artifact (the MAx-DNN-style layer-wise assignment
//!   only the Rust engine can execute). The sweep's (layer, ACU) pair
//!   evaluations run on a persistent [`ThreadPool`] with deterministic
//!   result ordering (see [`sweep_pairs`]); the artifact-free core
//!   ([`SweepCtx`]) is shared with the benches and tests.
//!
//! Results are printed as aligned tables and appended to
//! `artifacts/results/*.txt` so EXPERIMENTS.md can quote runs verbatim.

use std::cell::RefCell;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::compensate;
use crate::coordinator::ops::{self, InferVariant, ModelState, TrainVariant};
use crate::data::{self, Sizes, Split};
use crate::emulator::{Executor, ScratchArena, Style, Value};
use crate::graph::{retransform, ExecutionPlan, LayerMode, Manifest, Model, Policy};
use crate::lut::LutRegistry;
use crate::metrics;
use crate::quant::calib::CalibratorKind;
use crate::runtime::{weights, Runtime};
use crate::search::{self, acu_power, mcts, SearchMethod};
use crate::tensor::Tensor;
use crate::trainer;
use crate::util::fmt;
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

/// Per-model training hyper-parameters for the synthetic tasks.
/// (The paper trains on the real datasets; pre-training here replaces
/// "download pretrained model".)
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub pretrain_steps: usize,
    pub pretrain_lr: f32,
    pub qat_steps: usize,
    pub qat_lr: f32,
}

/// Learning rates assume the momentum-0.9 SGD baked into the train-step
/// executables (effective step ≈ lr / (1 - mu) at steady state).
/// Env overrides for sweeps: ADAPT_PRETRAIN_LR, ADAPT_PRETRAIN_STEPS.
pub fn hyper_for(model: &str) -> Hyper {
    let mut h = hyper_defaults(model);
    if let Ok(v) = std::env::var("ADAPT_PRETRAIN_LR") {
        if let Ok(lr) = v.parse() {
            h.pretrain_lr = lr;
        }
    }
    if let Ok(v) = std::env::var("ADAPT_PRETRAIN_STEPS") {
        if let Ok(s) = v.parse() {
            h.pretrain_steps = s;
        }
    }
    h
}

#[rustfmt::skip]
fn hyper_defaults(model: &str) -> Hyper {
    match model {
        "small_resnet" => Hyper { pretrain_steps: 360, pretrain_lr: 0.002, qat_steps: 48, qat_lr: 0.0005 },
        "small_vgg" => Hyper { pretrain_steps: 360, pretrain_lr: 0.004, qat_steps: 48, qat_lr: 0.001 },
        "squeezenet_mini" => Hyper { pretrain_steps: 420, pretrain_lr: 0.006, qat_steps: 48, qat_lr: 0.0015 },
        "lstm_imdb" => Hyper { pretrain_steps: 500, pretrain_lr: 0.2, qat_steps: 40, qat_lr: 0.02 },
        "vae_mnist" => Hyper { pretrain_steps: 300, pretrain_lr: 0.9, qat_steps: 40, qat_lr: 0.1 },
        _ => Hyper { pretrain_steps: 200, pretrain_lr: 0.004, qat_steps: 32, qat_lr: 0.001 },
    }
}

fn append_results(root: &Path, name: &str, text: &str) -> Result<()> {
    let dir = root.join("results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.txt"));
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)?;
    writeln!(f, "{text}")?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 1 — specs
// ---------------------------------------------------------------------------

pub fn table1(rt: &Runtime) -> String {
    let mut rows = Vec::new();
    for (name, m) in &rt.manifest.models {
        rows.push(vec![
            m.paper_row.clone(),
            name.clone(),
            m.kind.to_uppercase(),
            m.dataset.clone(),
            fmt::count(m.params_count),
            fmt::count(m.macs),
        ]);
    }
    fmt::table(
        &["Paper DNN", "This repo", "Type", "Dataset", "Params", "OPs/sample"],
        &rows,
    )
}

// ---------------------------------------------------------------------------
// Table 2 — quantization + retraining accuracy
// ---------------------------------------------------------------------------

/// One model's Table-2 row for one ACU operating point.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub model: String,
    pub fp32: f64,
    pub quant: f64,
    pub approx: f64,
    pub retrain: f64,
    pub retrain_time: Duration,
}

pub struct Table2Config {
    pub models: Vec<String>,
    pub sizes: Sizes,
    pub calibrator: CalibratorKind,
    pub percentile: f64,
    pub calib_batches: usize,
    pub eval_batches: Option<usize>,
    /// Scale factor on pretrain/QAT steps (smoke runs use < 1).
    pub steps_scale: f64,
    pub acu8: String,
    pub verbose: bool,
}

impl Default for Table2Config {
    fn default() -> Self {
        Table2Config {
            models: vec![],
            sizes: Sizes::default(),
            calibrator: CalibratorKind::Percentile,
            percentile: 0.999,
            calib_batches: 2,
            eval_batches: None,
            steps_scale: 1.0,
            acu8: "mul8s_1l2h_like".to_string(),
            verbose: false,
        }
    }
}

/// Ensure a model has trained fp32 weights (pre-train + snapshot if not).
pub fn ensure_pretrained(
    rt: &mut Runtime,
    name: &str,
    sizes: &Sizes,
    steps_scale: f64,
    verbose: bool,
) -> Result<ModelState> {
    let model = rt.manifest.model(name)?.clone();
    let trained = weights::trained_path(&rt.manifest.root, &model);
    if trained.exists() {
        return ModelState::load(rt, name, &trained);
    }
    let mut st = ModelState::load(rt, name, &weights::initial_path(&rt.manifest.root, &model))?;
    if model.loss == "none" || !model.artifacts.contains_key("fp32_train") {
        // GAN generator / Table-4-timing-only models: no training variant
        // was lowered; init weights are fine (timing is weight-agnostic).
        return Ok(st);
    }
    let hy = hyper_for(name);
    let steps = ((hy.pretrain_steps as f64 * steps_scale) as usize).max(4);
    let ds = data::load(&model.dataset, sizes);
    let log = if verbose { 50 } else { 0 };
    let tr = ops::train(rt, &mut st, TrainVariant::Fp32, &ds, steps, hy.pretrain_lr, None, log)?;
    if verbose {
        crate::obs::log::info(
            "pretrain",
            "done",
            &[
                ("model", name.to_string()),
                ("steps", tr.steps.to_string()),
                ("first_loss", format!("{:.4}", tr.first_loss)),
                ("last_loss", format!("{:.4}", tr.last_loss)),
                ("wall", fmt::dur(tr.wall)),
            ],
        );
    }
    st.save(&trained)?;
    Ok(st)
}

/// Run the Table-2 flow for one model at one operating point.
/// `bits12 == false` ⇒ 8-bit LUT ACU (cfg.acu8); `true` ⇒ 12-bit functional.
pub fn table2_row(
    rt: &mut Runtime,
    cfg: &Table2Config,
    name: &str,
    bits12: bool,
) -> Result<Table2Row> {
    let ds = data::load(&rt.manifest.model(name)?.dataset.clone(), &cfg.sizes);
    let mut st = ensure_pretrained(rt, name, &cfg.sizes, cfg.steps_scale, cfg.verbose)?;

    // FP32 baseline accuracy.
    let fp32 = ops::evaluate(rt, &st, InferVariant::Fp32, &ds, None, cfg.eval_batches)?;

    // Post-training calibration (§3.2.1, two batches).
    ops::calibrate(rt, &mut st, &ds, cfg.calib_batches, cfg.calibrator, cfg.percentile)?;

    let (quant, approx, lut_lit) = if bits12 {
        let q = ops::evaluate(rt, &st, InferVariant::Quant12, &ds, None, cfg.eval_batches)?;
        let a = ops::evaluate(rt, &st, InferVariant::Approx12, &ds, None, cfg.eval_batches)?;
        (q, a, None)
    } else {
        let exact_lit = ops::load_lut_lit(rt, "exact8")?;
        let q = ops::evaluate(rt, &st, InferVariant::ApproxLut, &ds, Some(&exact_lit), cfg.eval_batches)?;
        let acu_lit = ops::load_lut_lit(rt, &cfg.acu8)?;
        let a = ops::evaluate(rt, &st, InferVariant::ApproxLut, &ds, Some(&acu_lit), cfg.eval_batches)?;
        (q, a, Some(acu_lit))
    };

    // Approximation-aware retraining (§3.2.1).
    let hy = hyper_for(name);
    let steps = ((hy.qat_steps as f64 * cfg.steps_scale) as usize).max(2);
    let log = if cfg.verbose { 10 } else { 0 };
    let tr = if bits12 {
        ops::train(rt, &mut st, TrainVariant::Qat12, &ds, steps, hy.qat_lr, None, log)?
    } else {
        ops::train(rt, &mut st, TrainVariant::QatLut, &ds, steps, hy.qat_lr, lut_lit.as_ref(), log)?
    };

    let retrained = if bits12 {
        ops::evaluate(rt, &st, InferVariant::Approx12, &ds, None, cfg.eval_batches)?
    } else {
        ops::evaluate(rt, &st, InferVariant::ApproxLut, &ds, lut_lit.as_ref(), cfg.eval_batches)?
    };

    Ok(Table2Row {
        model: name.to_string(),
        fp32: fp32.accuracy,
        quant: quant.accuracy,
        approx: approx.accuracy,
        retrain: retrained.accuracy,
        retrain_time: tr.wall,
    })
}

/// Full Table 2 (both operating points over the retrainable models).
pub fn table2(rt: &mut Runtime, cfg: &Table2Config) -> Result<String> {
    let models: Vec<String> = if cfg.models.is_empty() {
        rt.manifest
            .models
            .iter()
            .filter(|(_, m)| m.table2)
            .map(|(n, _)| n.clone())
            .collect()
    } else {
        cfg.models.clone()
    };
    let mut out = String::new();
    for bits12 in [false, true] {
        let acu = if bits12 { "mul12s_2km_like (functional)" } else { cfg.acu8.as_str() };
        let meta = rt.manifest.luts.get(if bits12 { "exact8" } else { cfg.acu8.as_str() });
        let hdr = if bits12 {
            format!("ACU: {acu} — 12-bit trunc_out(k=4)")
        } else {
            let m = meta.unwrap();
            format!(
                "ACU: {acu} — MAE {:.4}%, MRE {:.3}%, power {:.2}x exact8",
                m.mae_pct, m.mre_pct, m.power
            )
        };
        out.push_str(&hdr);
        out.push('\n');
        let mut rows = Vec::new();
        for name in &models {
            let row = table2_row(rt, cfg, name, bits12)
                .with_context(|| format!("table2 row for {name}"))?;
            let quant_hdr = if bits12 { "12bit" } else { "8bit" };
            let _ = quant_hdr;
            rows.push(vec![
                row.model.clone(),
                fmt::pct(row.fp32),
                fmt::pct(row.quant),
                fmt::pct(row.approx),
                fmt::pct(row.retrain),
                fmt::dur(row.retrain_time),
            ]);
        }
        let cols = if bits12 {
            ["DNN", "FP32", "12bit", "12b approx.", "retrain", "time"]
        } else {
            ["DNN", "FP32", "8bit", "8b approx.", "retrain", "time"]
        };
        out.push_str(&fmt::table(&cols, &rows));
        out.push('\n');
    }
    append_results(&rt.manifest.root, "table2", &out)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 4 — emulation wall-clock
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table4Row {
    pub model: String,
    pub native: Duration,
    pub baseline: Duration,
    pub adapt_xla: Duration,
    pub adapt_rust: Duration,
    pub samples: usize,
}

pub struct Table4Config {
    pub models: Vec<String>,
    pub sizes: Sizes,
    pub eval_batches: usize,
    pub acu: String,
    /// Skip the slow scalar baseline (for smoke runs).
    pub skip_baseline: bool,
    pub threads: usize,
    pub verbose: bool,
}

impl Default for Table4Config {
    fn default() -> Self {
        Table4Config {
            models: vec![],
            sizes: Sizes::default(),
            eval_batches: 2,
            acu: "mul8s_1l2h_like".to_string(),
            skip_baseline: false,
            threads: crate::util::threadpool::default_threads(),
            verbose: false,
        }
    }
}

/// Time one model across the four engines on identical batches.
pub fn table4_row(rt: &mut Runtime, cfg: &Table4Config, name: &str) -> Result<Table4Row> {
    let model = rt.manifest.model(name)?.clone();
    let ds = data::load(&model.dataset, &cfg.sizes);
    let bs = rt.manifest.batch;
    let nb = cfg.eval_batches.max(1);
    let st = ensure_pretrained(rt, name, &cfg.sizes, 1.0, cfg.verbose)?;

    // Calibrate for the approx paths (outside the timed regions).
    let mut st = st;
    if model.loss != "none" || model.n_scales > 0 {
        ops::calibrate(rt, &mut st, &ds, 2, CalibratorKind::Percentile, 0.999)?;
    }
    let lut_lit = ops::load_lut_lit(rt, &cfg.acu)?;
    let scales = st.act_scales.clone().unwrap_or_default();
    let params = st.params_tensors()?;
    let luts = LutRegistry::from_manifest(&rt.manifest);

    let make_input = |bi: usize| -> Result<Value> {
        Ok(if model.input_dtype == "i32" {
            Value::I(ds.eval.batch_tensor_i(bi, bs))
        } else {
            Value::F(ds.eval.batch_tensor(bi, bs))
        })
    };

    // --- native: XLA fp32 (the paper's "Native CPU" PyTorch column) ----
    rt.prepare(name, "fp32_infer")?;
    let t0 = Instant::now();
    for bi in 0..nb {
        let x = ops::batch_input(&model, &ds.eval, bi, bs)?;
        let _ = ops::infer_batch(rt, &st, InferVariant::Fp32, &x, None)?;
    }
    let native = t0.elapsed();

    // --- AdaPT (ours): XLA approx path (Pallas LUT kernel) --------------
    rt.prepare(name, "approx_infer")?;
    let t0 = Instant::now();
    for bi in 0..nb {
        let x = ops::batch_input(&model, &ds.eval, bi, bs)?;
        let _ = ops::infer_batch(rt, &st, InferVariant::ApproxLut, &x, Some(&lut_lit))?;
    }
    let adapt_xla = t0.elapsed();

    // --- baseline: naive scalar LUT emulation (Rust) --------------------
    let plan = retransform(&model, &Policy::all(LayerMode::lut(cfg.acu.as_str())));
    let baseline = if cfg.skip_baseline {
        Duration::ZERO
    } else {
        let exec = Executor::new(
            &model,
            params.clone(),
            plan.clone(),
            scales.clone(),
            &luts,
            Style::Naive,
        )?;
        let t0 = Instant::now();
        for bi in 0..nb {
            let _ = exec.forward(make_input(bi)?)?;
        }
        t0.elapsed()
    };

    // --- optimized Rust engine (the paper's own AVX2+OpenMP design) -----
    let exec = Executor::new(
        &model,
        params,
        plan,
        scales,
        &luts,
        Style::Optimized {
            threads: cfg.threads,
        },
    )?;
    let t0 = Instant::now();
    for bi in 0..nb {
        let _ = exec.forward(make_input(bi)?)?;
    }
    let adapt_rust = t0.elapsed();

    Ok(Table4Row {
        model: name.to_string(),
        native,
        baseline,
        adapt_xla,
        adapt_rust,
        samples: nb * bs,
    })
}

pub fn table4(rt: &mut Runtime, cfg: &Table4Config) -> Result<String> {
    let models: Vec<String> = if cfg.models.is_empty() {
        rt.manifest.models.keys().cloned().collect()
    } else {
        cfg.models.clone()
    };
    let mut rows = Vec::new();
    for name in &models {
        let r = table4_row(rt, cfg, name).with_context(|| format!("table4 row {name}"))?;
        if cfg.verbose {
            crate::obs::log::info(
                "table4",
                "row done",
                &[
                    ("model", name.to_string()),
                    ("samples", r.samples.to_string()),
                ],
            );
        }
        let speedup = |a: Duration, b: Duration| -> String {
            if b.is_zero() || a.is_zero() {
                "-".into()
            } else {
                format!("{:.1}x", b.as_secs_f64() / a.as_secs_f64())
            }
        };
        let best_adapt = r.adapt_xla.min(if r.adapt_rust.is_zero() {
            r.adapt_xla
        } else {
            r.adapt_rust
        });
        rows.push(vec![
            name.clone(),
            fmt::dur(r.native),
            fmt::dur(r.baseline),
            fmt::dur(r.adapt_xla),
            fmt::dur(r.adapt_rust),
            speedup(best_adapt, r.baseline),
        ]);
    }
    let out = fmt::table(
        &[
            "DNN",
            "Native (XLA fp32)",
            "Baseline approx.",
            "AdaPT (XLA)",
            "AdaPT (Rust opt)",
            "Speed-up vs Baseline",
        ],
        &rows,
    );
    append_results(&rt.manifest.root, "table4", &out)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// ACU ablation (ALWANN-style accuracy/power sweep)
// ---------------------------------------------------------------------------

pub fn ablation(rt: &mut Runtime, model_name: &str, sizes: &Sizes, eval_batches: Option<usize>) -> Result<String> {
    let ds = data::load(&rt.manifest.model(model_name)?.dataset.clone(), sizes);
    let mut st = ensure_pretrained(rt, model_name, sizes, 1.0, false)?;
    ops::calibrate(rt, &mut st, &ds, 2, CalibratorKind::Percentile, 0.999)?;
    let fp32 = ops::evaluate(rt, &st, InferVariant::Fp32, &ds, None, eval_batches)?;
    let mut rows = vec![vec![
        "fp32".to_string(),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        fmt::pct(fp32.accuracy),
    ]];
    let acus: Vec<String> = rt.manifest.luts.keys().cloned().collect();
    for acu in acus {
        let meta = rt.manifest.luts[&acu].clone();
        let lit = ops::load_lut_lit(rt, &acu)?;
        let ev = ops::evaluate(rt, &st, InferVariant::ApproxLut, &ds, Some(&lit), eval_batches)?;
        rows.push(vec![
            acu.clone(),
            format!("{:.3}%", meta.mre_pct),
            format!("{:.4}%", meta.mae_pct),
            format!("{:.2}x", meta.power),
            fmt::pct(ev.accuracy),
        ]);
    }
    let out = fmt::table(
        &["ACU", "MRE", "MAE", "power", &format!("{model_name} accuracy")],
        &rows,
    );
    append_results(&rt.manifest.root, "ablation", &out)?;
    Ok(out)
}

// ---------------------------------------------------------------------------
// Layer sensitivity + greedy mixed-ACU search (heterogeneous plans)
// ---------------------------------------------------------------------------

/// Configuration for [`layer_sensitivity`].
pub struct SensitivityConfig {
    pub model: String,
    pub sizes: Sizes,
    /// Eval batches per plan evaluation (the sweep runs many plans).
    pub eval_batches: usize,
    /// Candidate ACUs tried per layer.
    pub acus: Vec<String>,
    /// Reference ACU every layer starts from (the exact-quantized point).
    pub reference: String,
    /// Allowed absolute accuracy drop vs the reference plan (e.g. 0.02).
    pub budget: f64,
    /// Total GEMM thread budget, split across the sweep workers.
    pub threads: usize,
    /// Sweep pool workers evaluating (layer, ACU) pairs concurrently
    /// (1 = sequential; default `ADAPT_THREADS`). The emitted plan is
    /// byte-identical at every worker count.
    pub sweep_workers: usize,
    /// QAT-retrain the greedy mixed plan on the emulator for this many
    /// epochs after the search (0 = off) — the plan → retrain loop in one
    /// command (`adapt sensitivity … --retrain-epochs N`).
    pub retrain_epochs: usize,
    /// Learning rate for the post-search retraining.
    pub retrain_lr: f32,
    /// Shuffle seed for the post-search retraining and the MCTS playout
    /// streams.
    pub seed: u64,
    /// Whole-plan search strategy (greedy, or MCTS warm-started by
    /// greedy's plan).
    pub search: SearchMethod,
    /// Fresh plan-evaluation budget for MCTS (0 = auto: the sweep size +
    /// greedy's trial count, at least 16).
    pub search_evals: usize,
    /// QAT-in-the-loop leaf re-scoring: retrain the top-N searched plans
    /// with a short `trainer::fit` run before picking the winner (MCTS
    /// only; 0 = off).
    pub retrain_leaves: usize,
    /// Score compensated candidates: fit a [`compensate::CompTable`] over
    /// every (layer, candidate) pair up front and stamp each evaluated
    /// plan with its calibrated correction terms, so greedy and MCTS see
    /// the accuracy the compensated kernels actually deliver.
    pub compensate: bool,
    pub verbose: bool,
}

impl Default for SensitivityConfig {
    fn default() -> Self {
        SensitivityConfig {
            model: "small_vgg".to_string(),
            sizes: Sizes::default(),
            eval_batches: 2,
            acus: vec![
                "mul8s_1l2h_like".to_string(),
                "drum8_6".to_string(),
                "trunc_out8_4".to_string(),
            ],
            reference: "exact8".to_string(),
            budget: 0.02,
            threads: crate::util::threadpool::default_threads(),
            sweep_workers: crate::util::threadpool::default_threads(),
            retrain_epochs: 0,
            retrain_lr: 0.002,
            seed: 0x5EED,
            search: SearchMethod::Greedy,
            search_evals: 0,
            retrain_leaves: 0,
            compensate: false,
            verbose: false,
        }
    }
}

/// One pre-extracted evaluation batch (inputs + supervision), so the
/// sweep core runs anywhere the Rust engines do — no `Runtime`, no
/// `Dataset` (benches and tests feed synthetic batches directly).
pub struct EvalBatch {
    pub input: Value,
    pub labels: Vec<i32>,
    /// Reconstruction target (metric == "pixel"), else empty.
    pub target: Vec<f32>,
}

impl EvalBatch {
    /// Extract batch `bi` of a split in the model's input dtype.
    pub fn from_split(model: &Model, split: &Split, bi: usize, bs: usize) -> EvalBatch {
        let input = if model.input_dtype == "i32" {
            Value::I(split.batch_tensor_i(bi, bs))
        } else {
            Value::F(split.batch_tensor(bi, bs))
        };
        let target = if model.metric == "pixel" {
            split.batch_f(bi, bs)
        } else {
            vec![]
        };
        EvalBatch {
            input,
            labels: split.batch_labels(bi, bs),
            target,
        }
    }
}

/// Shared immutable context for plan evaluations: everything a sweep
/// worker needs, crossing into pool jobs behind one `Arc`.
pub struct SweepCtx {
    pub model: Model,
    pub params: Vec<Tensor>,
    pub scales: Vec<f32>,
    pub luts: LutRegistry,
    pub batches: Vec<EvalBatch>,
    pub bs: usize,
    /// GEMM thread budget for ONE plan evaluation run inline (the base
    /// accuracy, the greedy search, the sequential sweep). The pooled
    /// sweep divides this budget by the pool size per job so concurrent
    /// workers never oversubscribe the cores.
    pub gemm_threads: usize,
    /// When set, every evaluated plan is stamped with these calibrated
    /// compensation terms for its current mode assignment
    /// ([`compensate::apply_table`]) before execution — the single hook
    /// that makes the sweep, greedy and MCTS all score *compensated*
    /// candidates without any change to the search code.
    pub comp: Option<compensate::CompTable>,
}

thread_local! {
    /// Per-worker warm scratch arena: a persistent pool worker threads one
    /// arena through every plan it evaluates ([`Executor::with_arena`]).
    static SWEEP_ARENA: RefCell<Option<ScratchArena>> = const { RefCell::new(None) };
}

impl SweepCtx {
    /// Evaluate one heterogeneous plan on the Rust optimized engine with
    /// the context's full GEMM thread budget.
    pub fn eval_plan(&self, plan: ExecutionPlan) -> Result<f64> {
        self.eval_plan_threads(plan, self.gemm_threads)
    }

    /// [`eval_plan`](Self::eval_plan) at an explicit GEMM thread count
    /// (the pooled sweep runs each job at `gemm_threads / pool size`).
    /// Bit-deterministic: the result depends only on the plan and the
    /// context, never on thread count or which worker runs it (row
    /// chunks are disjoint and each row is computed sequentially).
    pub fn eval_plan_threads(&self, plan: ExecutionPlan, threads: usize) -> Result<f64> {
        self.eval_plan_params(plan, self.params.clone(), threads)
    }

    /// [`eval_plan_threads`](Self::eval_plan_threads) with substitute
    /// weights — the MCTS QAT-in-the-loop mode scores retrained leaves
    /// through the same path every other evaluation takes.
    pub fn eval_plan_params(
        &self,
        plan: ExecutionPlan,
        params: Vec<Tensor>,
        threads: usize,
    ) -> Result<f64> {
        let mut plan = plan;
        if let Some(table) = &self.comp {
            compensate::apply_table(table, &mut plan);
        }
        let arena = SWEEP_ARENA.with(|slot| slot.borrow_mut().take()).unwrap_or_default();
        let exec = Executor::with_arena(
            &self.model,
            params,
            plan,
            self.scales.clone(),
            &self.luts,
            Style::Optimized {
                threads: threads.max(1),
            },
            arena,
        )?;
        let mut acc = 0.0;
        let mut samples = 0usize;
        for b in &self.batches {
            let out = exec.forward(b.input.clone())?;
            let out_dim = out.data.len() / self.bs;
            acc += metrics::compute(&self.model.metric, &out.data, out_dim, &b.labels, &b.target)
                * self.bs as f64;
            samples += self.bs;
        }
        SWEEP_ARENA.with(|slot| *slot.borrow_mut() = Some(exec.into_arena()));
        Ok(acc / samples.max(1) as f64)
    }

    /// Quantizable (node id, layer name) pairs of the model, sweep order.
    pub fn layers(&self) -> Vec<(usize, String)> {
        self.model
            .nodes
            .iter()
            .filter(|n| n.op.is_quantizable())
            .map(|n| (n.id, n.op.layer_name().unwrap_or_default().to_string()))
            .collect()
    }
}


/// Per-layer worst accuracy drop from [`sweep_pairs`] output (layer-major,
/// ACU-minor — the one place that indexing contract is interpreted).
pub fn worst_drops(base_acc: f64, accs: &[f64], n_layers: usize, n_acus: usize) -> Vec<f64> {
    let mut wd = vec![0.0f64; n_layers];
    for li in 0..n_layers {
        for ai in 0..n_acus {
            wd[li] = wd[li].max(base_acc - accs[li * n_acus + ai]);
        }
    }
    wd
}

/// Evaluate every (layer, ACU) single-swap plan against `reference`.
///
/// Returns accuracies in layer-major, ACU-minor order — identical whether
/// the pairs run sequentially (`pool == None`) or on a persistent worker
/// pool ([`ThreadPool::run_ordered`] restores submission order, and each
/// evaluation is bit-deterministic).
pub fn sweep_pairs(
    ctx: &Arc<SweepCtx>,
    reference: &ExecutionPlan,
    layers: &[(usize, String)],
    acus: &[String],
    pool: Option<&ThreadPool>,
) -> Result<Vec<f64>> {
    let plan_for = |id: usize, acu: &str| {
        let mut plan = reference.clone();
        plan.modes.insert(id, LayerMode::lut(acu));
        plan
    };
    match pool {
        Some(pool) if pool.threads() > 1 => {
            // Split the GEMM thread budget across the concurrent workers;
            // inline evaluations elsewhere keep the full budget.
            let per_job = (ctx.gemm_threads / pool.threads()).max(1);
            let mut jobs = Vec::with_capacity(layers.len() * acus.len());
            for (id, _) in layers {
                for acu in acus {
                    let ctx = Arc::clone(ctx);
                    let plan = plan_for(*id, acu);
                    jobs.push(move || ctx.eval_plan_threads(plan, per_job));
                }
            }
            pool.run_ordered(jobs).into_iter().collect()
        }
        _ => {
            let mut out = Vec::with_capacity(layers.len() * acus.len());
            for (id, _) in layers {
                for acu in acus {
                    out.push(ctx.eval_plan(plan_for(*id, acu))?);
                }
            }
            Ok(out)
        }
    }
}

/// Greedy mixed-ACU search: most tolerant layers first, each assigned the
/// cheapest candidate that keeps the cumulative plan within `budget` of
/// `base_acc`. Inherently sequential (every step depends on the plan so
/// far), so it is byte-identical after a sequential or a parallel sweep.
/// The third return is the number of plan evaluations spent — the budget
/// MCTS is held to for equal-cost comparisons.
#[allow(clippy::too_many_arguments)]
pub fn greedy_mixed(
    ctx: &SweepCtx,
    reference: &ExecutionPlan,
    reference_acu: &str,
    base_acc: f64,
    layers: &[(usize, String)],
    worst_drop: &[f64],
    acus: &[String],
    budget: f64,
) -> Result<(ExecutionPlan, f64, usize)> {
    let mut order: Vec<usize> = (0..layers.len()).collect();
    order.sort_by(|&a, &b| worst_drop[a].total_cmp(&worst_drop[b]));
    let mut candidates = acus.to_vec();
    candidates.sort_by(|a, b| acu_power(a).total_cmp(&acu_power(b)));
    let mut plan = reference.clone();
    let mut mixed_acc = base_acc;
    let mut trials = 0usize;
    for &li in &order {
        let (id, _) = &layers[li];
        for acu in &candidates {
            if acu_power(acu) >= acu_power(reference_acu) {
                continue; // only cheaper-than-reference ACUs are wins
            }
            let mut trial = plan.clone();
            trial.modes.insert(*id, LayerMode::lut(acu.as_str()));
            let acc = ctx.eval_plan(trial.clone())?;
            trials += 1;
            if base_acc - acc <= budget {
                plan = trial;
                mixed_acc = acc;
                break; // candidates are power-sorted: first fit is cheapest
            }
        }
    }
    Ok((plan, mixed_acc, trials))
}

/// Everything one sensitivity/search run produced: the human report, a
/// machine-readable summary (search method + seed + evaluation budget in
/// the header, so the plan is reproducible from the artifact alone), and
/// the exact plan JSON that was written to disk.
pub struct SensitivityOutcome {
    pub report: String,
    pub json: Json,
    pub plan_json: String,
}

/// Per-layer ACU sensitivity sweep + mixed-ACU plan search.
///
/// 1. Evaluate the homogeneous reference plan (every layer on
///    `cfg.reference`).
/// 2. For each quantizable layer × candidate ACU, evaluate the plan with
///    only that layer swapped; record the accuracy drop (the layer's
///    sensitivity to that ACU).
/// 3. Rank layers by their worst drop, then search: greedy assigns each
///    layer — most tolerant first — the lowest-power candidate that keeps
///    the *cumulative* mixed plan within `cfg.budget` of the reference;
///    `--search mcts` additionally runs [`mcts::search`] warm-started by
///    greedy's plan (so it can only improve on it) under an explicit
///    fresh-evaluation budget.
///
/// The chosen plan is saved as `artifacts/results/plan_<model>.json` with
/// a `provenance` field (`"greedy"` / `"mcts:<seed>/<budget>"`), a
/// first-class artifact `adapt plan --plan-file` / the executor can reload.
///
/// The sweep's (layer, ACU) pair evaluations run on a persistent
/// [`ThreadPool`] of `cfg.sweep_workers` workers; results are re-ordered
/// deterministically, so the report, the searched plan and the saved
/// plan JSON are byte-identical at every worker count.
pub fn layer_sensitivity(rt: &mut Runtime, cfg: &SensitivityConfig) -> Result<SensitivityOutcome> {
    let model = rt.manifest.model(&cfg.model)?.clone();
    let ds = data::load(&model.dataset, &cfg.sizes);
    let mut st = ensure_pretrained(rt, &cfg.model, &cfg.sizes, 1.0, cfg.verbose)?;
    ops::calibrate(rt, &mut st, &ds, 2, CalibratorKind::Percentile, 0.999)?;
    let params = st.params_tensors()?;
    let scales = st
        .act_scales
        .clone()
        .context("calibration produced no scales")?;
    let luts = LutRegistry::from_manifest(&rt.manifest);
    let bs = rt.manifest.batch;
    let nb = cfg.eval_batches.max(1).min(ds.eval.n_batches(bs).max(1));
    let sweep_workers = cfg.sweep_workers.max(1);
    let batches: Vec<EvalBatch> = (0..nb)
        .map(|bi| EvalBatch::from_split(&model, &ds.eval, bi, bs))
        .collect();
    // Optional: fit the compensation table over every (layer, candidate)
    // pair up front, so all downstream plan evaluations score compensated
    // candidates (and the saved plan carries its terms).
    let comp = if cfg.compensate {
        let mut modes: Vec<LayerMode> =
            cfg.acus.iter().map(|a| LayerMode::lut(a.as_str())).collect();
        modes.push(LayerMode::lut(cfg.reference.as_str()));
        let bits = compensate::needed_bits(modes.iter())?;
        let calib = compensate::collect(
            &model,
            &params,
            &ds.train,
            bs,
            2,
            &scales,
            &bits,
            cfg.threads.max(1),
        )?;
        let layer_ids: Vec<usize> = model
            .nodes
            .iter()
            .filter(|n| n.op.is_quantizable())
            .map(|n| n.id)
            .collect();
        Some(compensate::comp_table(
            &model, &params, &scales, &calib, &layer_ids, &modes,
        )?)
    } else {
        None
    };
    // Inline evaluations (base accuracy, greedy search) get the full GEMM
    // thread budget; sweep_pairs divides it per pooled job itself.
    let ctx = Arc::new(SweepCtx {
        model,
        params,
        scales,
        luts,
        batches,
        bs,
        gemm_threads: cfg.threads.max(1),
        comp,
    });
    let layers = ctx.layers();

    let reference = retransform(
        &ctx.model,
        &Policy::all(LayerMode::lut(cfg.reference.as_str())),
    );
    let base_acc = ctx.eval_plan(reference.clone())?;

    // --- per-layer sweep: one plan per (layer, ACU), pool-parallel -------
    let pool = if sweep_workers > 1 {
        Some(ThreadPool::new(sweep_workers))
    } else {
        None
    };
    let pair_accs = sweep_pairs(&ctx, &reference, &layers, &cfg.acus, pool.as_ref())?;

    let worst_drop = worst_drops(base_acc, &pair_accs, layers.len(), cfg.acus.len());
    let mut rows = Vec::new();
    for (li, (_, name)) in layers.iter().enumerate() {
        let mut row = vec![name.clone()];
        for ai in 0..cfg.acus.len() {
            let drop = base_acc - pair_accs[li * cfg.acus.len() + ai];
            row.push(format!("{:+.2}", -100.0 * drop));
        }
        row.push(format!("{:.2}", 100.0 * worst_drop[li]));
        if cfg.verbose {
            crate::obs::log::info(
                "sensitivity",
                "layer swept",
                &[
                    ("model", cfg.model.clone()),
                    ("layer", name.to_string()),
                    ("worst_drop_pts", format!("{:.2}", 100.0 * worst_drop[li])),
                ],
            );
        }
        rows.push(row);
    }

    // --- greedy mixed search, most tolerant layers first -----------------
    let (greedy_plan, greedy_acc, greedy_evals) = greedy_mixed(
        &ctx,
        &reference,
        &cfg.reference,
        base_acc,
        &layers,
        &worst_drop,
        &cfg.acus,
        cfg.budget,
    )?;

    // --- optional MCTS, warm-started by greedy's plan --------------------
    let budget_evals = if cfg.search_evals == 0 {
        (pair_accs.len() + greedy_evals).max(16)
    } else {
        cfg.search_evals
    };
    let mut mcts_outcome = None;
    let (mut plan, mixed_acc) = match cfg.search {
        SearchMethod::Greedy => (greedy_plan.clone(), greedy_acc),
        SearchMethod::Mcts => {
            let space = mcts::SearchSpace::build(
                &ctx.model,
                reference.clone(),
                &cfg.reference,
                base_acc,
                cfg.budget,
                &layers,
                &pair_accs,
                &cfg.acus,
            )?;
            let mcfg = mcts::MctsConfig {
                seed: cfg.seed,
                evals: budget_evals,
                ..mcts::MctsConfig::default()
            };
            let rc_store;
            let rc = if cfg.retrain_leaves > 0 {
                rc_store = mcts::RetrainCtx {
                    train: &ds.train,
                    leaves: cfg.retrain_leaves,
                    epochs: cfg.retrain_epochs.max(1),
                    lr: cfg.retrain_lr,
                    seed: cfg.seed,
                };
                Some(&rc_store)
            } else {
                None
            };
            let out = mcts::search(
                &ctx,
                space,
                &mcfg,
                Some((&greedy_plan, greedy_acc)),
                pool.as_ref(),
                rc,
            )?;
            let picked = (out.plan.clone(), out.accuracy);
            mcts_outcome = Some(out);
            picked
        }
    };
    // The searched plan itself carries the terms it was scored with (the
    // evaluations stamp internal clones; the artifact must match them).
    if let Some(table) = &ctx.comp {
        compensate::apply_table(table, &mut plan);
    }
    let plan = plan;
    let provenance = {
        let base = match cfg.search {
            SearchMethod::Greedy => "greedy".to_string(),
            SearchMethod::Mcts => format!("mcts:{}/{}", cfg.seed, budget_evals),
        };
        if cfg.compensate {
            format!("{base}+comp")
        } else {
            base
        }
    };

    let macs = search::layer_macs(&ctx.model);
    let outs = search::layer_outputs(&ctx.model);
    let plan_power = |p: &ExecutionPlan| -> f64 { search::plan_cost_macs(&macs, p) };

    // --- report + plan artifact ------------------------------------------
    let mut headers: Vec<&str> = vec!["layer"];
    for acu in &cfg.acus {
        headers.push(acu.as_str());
    }
    headers.push("worst drop (pts)");
    // Mirror sweep_pairs' per-job thread split in the report header.
    let per_job_threads = if sweep_workers > 1 {
        (ctx.gemm_threads / sweep_workers).max(1)
    } else {
        ctx.gemm_threads
    };
    let mut out = format!(
        "Layer sensitivity on {} (reference {}, {} eval batches, budget {:.1} pts, \
         {} sweep workers x {} gemm threads)\n\
         search: {} (seed {:#x}, eval budget {})\n\
         reference accuracy: {}\n\n",
        cfg.model,
        cfg.reference,
        nb,
        100.0 * cfg.budget,
        sweep_workers,
        per_job_threads,
        cfg.search.label(),
        cfg.seed,
        budget_evals,
        fmt::pct(base_acc),
    );
    out.push_str(&fmt::table(&headers, &rows));
    out.push_str(&format!(
        "\nGreedy mixed-ACU plan (accuracy {}, {:+.2} pts vs reference, \
         {} evals, MAC-weighted power {:.2}x -> {:.2}x)\n",
        fmt::pct(greedy_acc),
        100.0 * (greedy_acc - base_acc),
        greedy_evals,
        plan_power(&reference),
        plan_power(&greedy_plan),
    ));
    if let Some(m) = &mcts_outcome {
        out.push_str(&format!(
            "MCTS plan (accuracy {}, {:+.2} pts vs reference, {} evals + {} cache hits, \
             {} playouts, {} leaves retrained, MAC-weighted power {:.2}x, savings {:.1}%)\n",
            fmt::pct(m.accuracy),
            100.0 * (m.accuracy - base_acc),
            m.evals,
            m.cache_hits,
            m.playouts,
            m.retrained,
            m.cost,
            100.0 * m.savings,
        ));
    }
    if cfg.compensate {
        out.push_str(&format!(
            "Compensation: {} layer(s) carry calibrated terms, \
             comp-aware power {:.3}x (adds at {:.2}x MAC)\n",
            plan.compensation.len(),
            search::plan_cost_comp(&macs, &outs, &plan),
            search::COMP_ADD_POWER,
        ));
    }
    out.push_str(&format!(
        "\nSelected plan ({}):\n{}",
        provenance,
        plan.describe(&ctx.model),
    ));

    let dir = rt.manifest.root.join("results");
    std::fs::create_dir_all(&dir)?;
    let plan_path = dir.join(format!("plan_{}.json", cfg.model));
    let plan_json = plan.to_json_with(&ctx.model, Some(&provenance));
    std::fs::write(&plan_path, &plan_json)?;
    out.push_str(&format!("\nplan saved to {}\n", plan_path.display()));

    // --- optional: QAT-retrain the mixed plan in the same command -------
    if cfg.retrain_epochs > 0 {
        let tcfg = trainer::TrainConfig {
            epochs: cfg.retrain_epochs,
            lr: cfg.retrain_lr,
            momentum: 0.9,
            batch: bs,
            seed: cfg.seed,
            threads: ctx.gemm_threads,
            max_batches: None,
            log_every: if cfg.verbose { 10 } else { 0 },
            approx_backward: None,
        };
        let fit = trainer::fit(
            &ctx.model,
            ctx.params.clone(),
            &plan,
            &ctx.scales,
            &ctx.luts,
            &ds.train,
            &tcfg,
        )?;
        let retrained = trainer::evaluate(
            &ctx.model,
            fit.params.clone(),
            &plan,
            &ctx.scales,
            &ctx.luts,
            &ds.eval,
            bs,
            nb,
            ctx.gemm_threads,
        )?;
        let (l0, l1) = fit.improvement();
        out.push_str(&format!(
            "\nQAT retrain of the mixed plan ({} epochs x {} steps, lr {}): \
             accuracy {} -> {} ({:+.2} pts vs reference), loss {l0:.4} -> {l1:.4}\n",
            cfg.retrain_epochs,
            fit.steps / cfg.retrain_epochs.max(1),
            cfg.retrain_lr,
            fmt::pct(mixed_acc),
            fmt::pct(retrained),
            100.0 * (retrained - base_acc),
        ));
        let wpath = dir.join(format!("retrained_{}.bin", cfg.model));
        weights::save_params(&fit.params, &wpath)?;
        out.push_str(&format!("retrained weights saved to {}\n", wpath.display()));
    }

    append_results(&rt.manifest.root, "sensitivity", &out)?;

    // Machine-readable summary; the header carries everything needed to
    // reproduce the searched plan (method + seed + evaluation budget).
    let mut doc = std::collections::BTreeMap::new();
    doc.insert("model".to_string(), Json::Str(cfg.model.clone()));
    doc.insert("search".to_string(), Json::Str(cfg.search.label().to_string()));
    doc.insert("seed".to_string(), Json::Num(cfg.seed as f64));
    doc.insert("eval_budget".to_string(), Json::Num(budget_evals as f64));
    doc.insert("reference".to_string(), Json::Str(cfg.reference.clone()));
    doc.insert(
        "acus".to_string(),
        Json::Arr(cfg.acus.iter().map(|a| Json::Str(a.clone())).collect()),
    );
    doc.insert("eval_batches".to_string(), Json::Num(nb as f64));
    doc.insert("budget".to_string(), Json::Num(cfg.budget));
    doc.insert("base_accuracy".to_string(), Json::Num(base_acc));
    let mut g = std::collections::BTreeMap::new();
    g.insert("accuracy".to_string(), Json::Num(greedy_acc));
    g.insert("evals".to_string(), Json::Num(greedy_evals as f64));
    g.insert("power".to_string(), Json::Num(plan_power(&greedy_plan)));
    doc.insert("greedy".to_string(), Json::Obj(g));
    if let Some(m) = &mcts_outcome {
        let mut j = std::collections::BTreeMap::new();
        j.insert("accuracy".to_string(), Json::Num(m.accuracy));
        j.insert("evals".to_string(), Json::Num(m.evals as f64));
        j.insert("cache_hits".to_string(), Json::Num(m.cache_hits as f64));
        j.insert("playouts".to_string(), Json::Num(m.playouts as f64));
        j.insert("retrained".to_string(), Json::Num(m.retrained as f64));
        j.insert("power".to_string(), Json::Num(m.cost));
        j.insert("savings".to_string(), Json::Num(m.savings));
        j.insert("feasible".to_string(), Json::Bool(m.feasible));
        doc.insert("mcts".to_string(), Json::Obj(j));
    }
    doc.insert("accuracy".to_string(), Json::Num(mixed_acc));
    doc.insert("compensate".to_string(), Json::Bool(cfg.compensate));
    if cfg.compensate {
        doc.insert(
            "compensated_layers".to_string(),
            Json::Num(plan.compensation.len() as f64),
        );
        doc.insert(
            "comp_power".to_string(),
            Json::Num(search::plan_cost_comp(&macs, &outs, &plan)),
        );
    }
    doc.insert("provenance".to_string(), Json::Str(provenance));
    doc.insert("plan_path".to_string(), Json::Str(plan_path.display().to_string()));

    Ok(SensitivityOutcome {
        report: out,
        json: Json::Obj(doc),
        plan_json,
    })
}

// ---------------------------------------------------------------------------
// Emulator-native QAT retraining (adapt retrain) — artifact-free
// ---------------------------------------------------------------------------

/// Configuration for [`retrain_plan`] (the `adapt retrain` subcommand).
pub struct RetrainConfig {
    pub model: String,
    pub sizes: Sizes,
    pub epochs: usize,
    pub lr: f32,
    pub momentum: f32,
    /// Training/eval batch size (`None` = the manifest batch).
    pub batch: Option<usize>,
    pub seed: u64,
    pub threads: usize,
    pub eval_batches: usize,
    /// Snapshot the retrained weights to `trained/<model>_qat.bin`.
    pub save: bool,
    /// Approximate-gradient training: ACU registry name to route the
    /// backward transpose GEMMs through (`--approx-backward`).
    pub approx_backward: Option<String>,
    pub verbose: bool,
}

/// QAT-retrain `plan` on the Rust emulator — artifact-free: needs the
/// manifest + a weights blob + the Rust engines, but **no PJRT / HLO
/// artifacts** (calibration runs on the emulator's own fp32 taps via
/// [`trainer::calibrate_emulator`]). Any [`ExecutionPlan`] works,
/// including the heterogeneous mixed-ACU plans `adapt sensitivity`
/// saves. Deterministic for a fixed seed at any `ADAPT_THREADS`.
pub fn retrain_plan(manifest: &Manifest, plan: &ExecutionPlan, cfg: &RetrainConfig) -> Result<String> {
    let model = manifest.model(&cfg.model)?.clone();
    let ds = data::load(&model.dataset, &cfg.sizes);
    let trained = weights::trained_path(&manifest.root, &model);
    let wpath = if trained.exists() {
        trained
    } else {
        weights::initial_path(&manifest.root, &model)
    };
    let params = weights::load_params(&model, &wpath)?;
    let bs = cfg.batch.unwrap_or(manifest.batch).max(1);
    let threads = cfg.threads.max(1);
    let eval_batches = cfg.eval_batches.max(1);

    let scales = trainer::calibrate_emulator(
        &model,
        &params,
        &ds.train,
        bs,
        2,
        CalibratorKind::Percentile,
        0.999,
        threads,
    )?;
    let luts = LutRegistry::from_manifest(manifest);
    luts.preload(&plan.acus())?;

    let before = trainer::evaluate(
        &model, params.clone(), plan, &scales, &luts, &ds.eval, bs, eval_batches, threads,
    )?;
    let approx = cfg
        .approx_backward
        .as_deref()
        .map(trainer::ApproxGrad::from_acu)
        .transpose()?;
    let tcfg = trainer::TrainConfig {
        epochs: cfg.epochs,
        lr: cfg.lr,
        momentum: cfg.momentum,
        batch: bs,
        seed: cfg.seed,
        threads,
        max_batches: None,
        log_every: if cfg.verbose { 10 } else { 0 },
        approx_backward: approx,
    };
    let fit = trainer::fit(&model, params, plan, &scales, &luts, &ds.train, &tcfg)?;
    let after = trainer::evaluate(
        &model, fit.params.clone(), plan, &scales, &luts, &ds.eval, bs, eval_batches, threads,
    )?;

    let (l0, l1) = fit.improvement();
    let epoch_means: Vec<String> = fit
        .epoch_losses
        .iter()
        .map(|l| format!("{l:.4}"))
        .collect();
    let mut out = format!(
        "Emulator QAT retrain of {} ({} epochs x {} steps, lr {}, batch {bs}, seed {:#x})\n\
         weights: {}\n\
         plan:\n{}\
         accuracy: {} -> {}  ({:+.2} pts)\n\
         loss (per-epoch means): {}   ({l0:.4} -> {l1:.4})\n\
         wall: {}\n",
        cfg.model,
        cfg.epochs,
        fit.steps / cfg.epochs.max(1),
        cfg.lr,
        cfg.seed,
        wpath.display(),
        plan.describe(&model),
        fmt::pct(before),
        fmt::pct(after),
        100.0 * (after - before),
        epoch_means.join(", "),
        fmt::dur(fit.wall),
    );
    if let Some(ag) = approx {
        out.push_str(&format!("approx backward ACU: {} ({}-bit)\n", ag.name, ag.bits));
    }
    if cfg.save {
        let path = weights::retrained_path(&manifest.root, &model);
        weights::save_params(&fit.params, &path)?;
        out.push_str(&format!("retrained weights saved to {}\n", path.display()));
    }
    append_results(&manifest.root, "retrain", &out)?;
    Ok(out)
}
