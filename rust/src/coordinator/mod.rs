//! Layer-3 coordination: everything between the CLI and the runtime.
//!
//! * [`ops`] — model state + the primitive operations (inference, fp32
//!   pre-training, calibration, QAT retraining) driving the AOT
//!   executables. This is Fig. 1 + Fig. 2 as code.
//! * [`engine`] — the request-level inference engine: a pool of dynamic
//!   batchers over a shared bounded request queue (the serving-style face
//!   of the framework; each worker owns its PJRT runtime or Rust
//!   executor outright).
//! * [`experiments`] — harnesses that regenerate every table in the
//!   paper's evaluation (Tables 1–4) plus the ablations in DESIGN.md,
//!   including the pool-parallel per-layer ACU sensitivity sweep.
//! * [`features`] — the Table-3 functionality matrix.

pub mod engine;
pub mod experiments;
pub mod features;
pub mod ops;
