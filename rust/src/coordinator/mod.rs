//! Layer-3 coordination: everything between the CLI and the runtime.
//!
//! * [`ops`] — model state + the primitive operations (inference, fp32
//!   pre-training, calibration, QAT retraining) driving the AOT
//!   executables. This is Fig. 1 + Fig. 2 as code.
//! * [`engine`] — the request-level inference engine: a dynamic batcher in
//!   front of the fixed-batch executables (the serving-style face of the
//!   framework).
//! * [`experiments`] — harnesses that regenerate every table in the
//!   paper's evaluation (Tables 1–4) plus the ablations in DESIGN.md.
//! * [`features`] — the Table-3 functionality matrix.

pub mod engine;
pub mod experiments;
pub mod features;
pub mod ops;
