//! Calibrated error compensation for approximate plans — the
//! control-variate additive correction of Zervakis et al., "Leveraging
//! Highly Approximated Multipliers in DNN Inference" (2024).
//!
//! An approximate multiplier injects a *biased* error into every MAC:
//! for operands `(a, b)` the ACU returns `a·b + err(a, b)` with
//! `E[err] != 0` (Mitchell's logarithmic multiplier is biased low,
//! floor-truncation biased negative, …). Over a whole GEMM row the bias
//! accumulates into a per-output-channel offset that shifts logits and
//! wrecks accuracy long before the error *variance* does. The fix is
//! cheap: measure the expected accumulated error offline and subtract it.
//!
//! The pipeline here:
//!
//! 1. **Calibration** ([`collect`]) — run the fp32 forward over a few
//!    calibration batches with [`Executor::forward_taped`] (the same
//!    artifact-free tap machinery as
//!    [`crate::trainer::calibrate_emulator`]) and histogram each
//!    quantizable layer's *quantized operand distribution*: the im2col
//!    patch matrix for convs (padding zeros included — they are real GEMM
//!    operands), the activation matrix for linears, quantized at every
//!    candidate bitwidth with the layer's calibrated scale.
//! 2. **Error model** ([`compensation_for`]) — for a layer mode, evaluate
//!    the ACU's signed error `err(a, b) = acu(a, b) − a·b` (the
//!    [`crate::mult::Form`] closed form when the ACU has one, its
//!    behavioral function otherwise) against the operand histogram:
//!    `rowsum[b] = E_a[err(a, b)]`, then per output channel `n` sum
//!    `rowsum` over that channel's quantized weights — exactly the
//!    per-column quantization ([`crate::quant::weight_scales_per_col`])
//!    and group flattening the executor's prepare step uses, so the model
//!    predicts the real kernels' accumulated error. Dequantizing through
//!    `sa · ws[n]` gives the expected fp32 output offset; its negation is
//!    the correction, split into a `constant` (mean over channels) plus
//!    per-channel residuals.
//! 3. **Execution** — the terms ride in the plan
//!    ([`crate::graph::Compensation`]) and fold into the bias vector at
//!    executor prepare time: zero cost on the GEMM hot path, bit-identical
//!    across SIMD tiers and `ADAPT_THREADS`, and a plan without (or with
//!    all-zero) compensation executes byte-for-byte as before.
//!
//! Exact modes (`exact8`, `func:<bits>:0`) have identically zero error and
//! yield no compensation block. LSTMs are not compensated (gate-structured
//! outputs do not fit the per-output-channel correction model).
//!
//! Everything is deterministic: histogram accumulation and the fits are
//! sequential, and the taped forward is bit-identical at any thread count,
//! so the same calibration data produces byte-identical compensation terms
//! at `ADAPT_THREADS=1` and `=4`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::data::Split;
use crate::emulator::{Executor, Style, Value};
use crate::graph::{retransform, Compensation, ExecutionPlan, LayerMode, Model, Op, Policy};
use crate::mult::{self, Form};
use crate::quant;
use crate::tensor::{im2col_f32, Tensor};

/// Quantized-operand histogram of one layer at one bitwidth.
#[derive(Clone, Debug)]
pub struct LayerHist {
    pub node: usize,
    pub bits: u32,
    /// `counts[q + qmax]` = occurrences of quantized level `q`.
    pub counts: Vec<u64>,
    pub total: u64,
}

impl LayerHist {
    fn new(node: usize, bits: u32) -> LayerHist {
        let qmax = quant::qmax_for(bits) as usize;
        LayerHist {
            node,
            bits,
            counts: vec![0; 2 * qmax + 1],
            total: 0,
        }
    }

    fn observe(&mut self, xs: &[f32], sa: f32) {
        let qmax = quant::qmax_for(self.bits);
        for &x in xs {
            let q = quant::quantize_one(x, sa, qmax);
            self.counts[(q + qmax) as usize] += 1;
        }
        self.total += xs.len() as u64;
    }
}

/// Calibration artifact: per-(node, bits) operand histograms.
#[derive(Clone, Debug, Default)]
pub struct Calibration {
    pub hists: BTreeMap<(usize, u32), LayerHist>,
}

/// Distinct activation bitwidths a set of candidate modes quantizes at
/// (fp32 modes contribute nothing). Drives [`collect`].
pub fn needed_bits<'a>(modes: impl Iterator<Item = &'a LayerMode>) -> Result<Vec<u32>> {
    let mut set = std::collections::BTreeSet::new();
    for mode in modes {
        if let Some(bits) = mode_bits(mode)? {
            set.insert(bits);
        }
    }
    Ok(set.into_iter().collect())
}

/// Activation bitwidth of a mode (`None` for fp32).
pub fn mode_bits(mode: &LayerMode) -> Result<Option<u32>> {
    Ok(match mode {
        LayerMode::Fp32 => None,
        LayerMode::ApproxLut { acu } => Some(mult::get(acu)?.bits),
        LayerMode::ApproxFunc { bits, .. } => Some(*bits),
    })
}

/// The layer's effective activation scale at `bits` — identical to the
/// executor's rescale of the calibrated 8-bit scale to the node bitwidth.
fn sa_at(scales: &[f32], scale_idx: usize, bits: u32) -> f32 {
    scales[scale_idx] * (quant::qmax_for(8) as f32 / quant::qmax_for(bits) as f32)
}

/// Calibration pass: fp32 taped forward over `batches` batches of `split`,
/// histogramming every quantizable layer's operand distribution at each
/// bitwidth in `bits_list`. `scales` are the layer activation scales from
/// [`crate::trainer::calibrate_emulator`] (8-bit convention).
#[allow(clippy::too_many_arguments)]
pub fn collect(
    model: &Model,
    params: &[Tensor],
    split: &Split,
    batch: usize,
    batches: usize,
    scales: &[f32],
    bits_list: &[u32],
    threads: usize,
) -> Result<Calibration> {
    anyhow::ensure!(!bits_list.is_empty(), "compensation calibration needs at least one bitwidth");
    let plan = retransform(model, &Policy::all(LayerMode::Fp32));
    let luts = crate::lut::LutRegistry::in_memory();
    let exec = Executor::new(
        model,
        params.to_vec(),
        plan,
        vec![],
        &luts,
        Style::Optimized {
            threads: threads.max(1),
        },
    )?;
    let mut hists: BTreeMap<(usize, u32), LayerHist> = BTreeMap::new();
    let bs = batch.max(1);
    let tape_f = |tape: &[Option<Value>], id: usize| -> Result<Tensor> {
        match tape.get(id).and_then(|v| v.as_ref()) {
            Some(Value::F(t)) => Ok(t.clone()),
            _ => anyhow::bail!("compensation tape missing f32 value {id}"),
        }
    };
    for bi in 0..batches.max(1) {
        let tape = exec.forward_taped(Value::F(split.batch_tensor(bi, bs)))?;
        for node in &model.nodes {
            let (operands, scale_idx) = match &node.op {
                Op::Conv2d {
                    kh,
                    kw,
                    stride,
                    pad,
                    scale_idx,
                    ..
                } => {
                    let xin = tape_f(&tape, node.inputs[0])?;
                    (im2col_f32(&xin, *kh, *kw, *stride, *pad).data, *scale_idx)
                }
                Op::Linear { scale_idx, .. } => (tape_f(&tape, node.inputs[0])?.data, *scale_idx),
                Op::Lstm { .. } => bail!(
                    "LSTM models are not supported by compensation calibration"
                ),
                _ => continue,
            };
            for &bits in bits_list {
                let sa = sa_at(scales, scale_idx, bits);
                hists
                    .entry((node.id, bits))
                    .or_insert_with(|| LayerHist::new(node.id, bits))
                    .observe(&operands, sa);
            }
        }
    }
    Ok(Calibration { hists })
}

/// The ACU's signed product error for a mode, or `None` when the mode is
/// exact (fp32, `exact*`, `func:<bits>:0`) and needs no compensation.
fn mode_error_fn(mode: &LayerMode) -> Result<Option<(Box<dyn Fn(i64, i64) -> i64>, u32)>> {
    Ok(match mode {
        LayerMode::Fp32 => None,
        LayerMode::ApproxLut { acu } => {
            let m = mult::get(acu)?;
            if matches!(m.form, Form::Exact) {
                None
            } else {
                let fun = m.fun;
                Some((Box::new(move |a, b| fun(a, b) - a * b), m.bits))
            }
        }
        LayerMode::ApproxFunc { bits, trunc_k } => {
            if *trunc_k == 0 {
                None
            } else {
                let form = Form::TruncOut(*trunc_k);
                let bits = *bits;
                Some((Box::new(move |a, b| form.mul_i64(a, b) - a * b), bits))
            }
        }
    })
}

/// `rowsum[b + qmax] = E_a[err(a, b)]` over the operand histogram — the
/// expected error contribution of one MAC whose weight level is `b`.
fn rowsum_err(hist: &LayerHist, err: &dyn Fn(i64, i64) -> i64) -> Vec<f64> {
    let qmax = quant::qmax_for(hist.bits) as i64;
    let levels = (2 * qmax + 1) as usize;
    let mut row = vec![0.0f64; levels];
    if hist.total == 0 {
        return row;
    }
    for (idx, &count) in hist.counts.iter().enumerate() {
        if count == 0 {
            continue;
        }
        let a = idx as i64 - qmax;
        let w = count as f64;
        for (j, slot) in row.iter_mut().enumerate() {
            let b = j as i64 - qmax;
            *slot += w * err(a, b) as f64;
        }
    }
    let inv = 1.0 / hist.total as f64;
    for slot in &mut row {
        *slot *= inv;
    }
    row
}

/// Fit the additive correction of one layer under one mode: the negated
/// expected per-output-channel error, dequantized through the layer's
/// activation and per-column weight scales. Returns `None` for exact
/// modes, LSTM nodes, and identically-zero corrections.
pub fn compensation_for(
    model: &Model,
    params: &[Tensor],
    scales: &[f32],
    calib: &Calibration,
    node_id: usize,
    mode: &LayerMode,
) -> Result<Option<Compensation>> {
    let Some((err, bits)) = mode_error_fn(mode)? else {
        return Ok(None);
    };
    let node = model
        .nodes
        .iter()
        .find(|n| n.id == node_id)
        .with_context(|| format!("compensation for unknown node {node_id}"))?;
    let hist = calib
        .hists
        .get(&(node_id, bits))
        .with_context(|| format!("no {bits}-bit calibration histogram for node {node_id}"))?;
    let qmax = quant::qmax_for(bits) as i64;
    let row = rowsum_err(hist, err.as_ref());

    // Per-channel expected output error, through the same flattening +
    // per-column quantization as the executor's prepare step.
    let terms: Vec<f32> = match &node.op {
        Op::Conv2d {
            kh,
            kw,
            cin,
            cout,
            groups,
            scale_idx,
            ..
        } => {
            let w = &params[node.params[0]];
            let cin_g = cin / groups;
            let cout_g = cout / groups;
            let kf = kh * kw * cin_g;
            let sa = sa_at(scales, *scale_idx, bits);
            let mut terms = vec![0.0f32; *cout];
            let mut flat = Vec::with_capacity(kf * cout_g);
            for g in 0..*groups {
                flat.clear();
                for r in 0..kf {
                    let base = r * cout + g * cout_g;
                    flat.extend_from_slice(&w.data[base..base + cout_g]);
                }
                let ws = quant::weight_scales_per_col(&flat, kf, cout_g, bits);
                let wq = quant::quantize_weights_per_col(&flat, kf, cout_g, bits, &ws);
                for ci in 0..cout_g {
                    let mut esum = 0.0f64;
                    for r in 0..kf {
                        esum += row[(wq[r * cout_g + ci] as i64 + qmax) as usize];
                    }
                    terms[g * cout_g + ci] = -(esum as f32) * sa * ws[ci];
                }
            }
            terms
        }
        Op::Linear {
            din,
            dout,
            scale_idx,
            ..
        } => {
            let w = &params[node.params[0]];
            let sa = sa_at(scales, *scale_idx, bits);
            let ws = quant::weight_scales_per_col(&w.data, *din, *dout, bits);
            let wq = quant::quantize_weights_per_col(&w.data, *din, *dout, bits, &ws);
            let mut terms = vec![0.0f32; *dout];
            for (ci, term) in terms.iter_mut().enumerate() {
                let mut esum = 0.0f64;
                for r in 0..*din {
                    esum += row[(wq[r * dout + ci] as i64 + qmax) as usize];
                }
                *term = -(esum as f32) * sa * ws[ci];
            }
            terms
        }
        _ => return Ok(None),
    };

    if terms.iter().all(|&t| t == 0.0) {
        return Ok(None);
    }
    let mean = (terms.iter().map(|&t| t as f64).sum::<f64>() / terms.len() as f64) as f32;
    let channels: Vec<f32> = terms.iter().map(|&t| t - mean).collect();
    Ok(Some(Compensation {
        constant: mean,
        channels,
    }))
}

/// Attach calibrated compensation to every approximated conv/linear layer
/// of `plan` in place; returns how many layers got a block.
pub fn compensate_plan(
    model: &Model,
    params: &[Tensor],
    scales: &[f32],
    calib: &Calibration,
    plan: &mut ExecutionPlan,
) -> Result<usize> {
    let modes: Vec<(usize, LayerMode)> =
        plan.modes.iter().map(|(id, m)| (*id, m.clone())).collect();
    let mut applied = 0usize;
    for (id, mode) in modes {
        match compensation_for(model, params, scales, calib, id, &mode)? {
            Some(comp) => {
                plan.compensation.insert(id, comp);
                applied += 1;
            }
            None => {
                plan.compensation.remove(&id);
            }
        }
    }
    Ok(applied)
}

/// Precomputed `(node, mode label) -> Compensation` table for plan search:
/// every (layer, candidate mode) pair fits once up front, and
/// [`apply_table`] stamps a candidate plan in O(layers). Exact modes have
/// no entry.
pub type CompTable = BTreeMap<(usize, String), Compensation>;

/// Build the search-time compensation table for `layers` × `modes`.
pub fn comp_table(
    model: &Model,
    params: &[Tensor],
    scales: &[f32],
    calib: &Calibration,
    layers: &[usize],
    modes: &[LayerMode],
) -> Result<CompTable> {
    let mut table = CompTable::new();
    for &node_id in layers {
        for mode in modes {
            if let Some(comp) =
                compensation_for(model, params, scales, calib, node_id, mode)?
            {
                table.insert((node_id, mode.label()), comp);
            }
        }
    }
    Ok(table)
}

/// Stamp `plan` with the table's terms for its current mode assignment
/// (clearing entries for modes without one, e.g. exact or fp32).
pub fn apply_table(table: &CompTable, plan: &mut ExecutionPlan) {
    let modes: Vec<(usize, String)> = plan
        .modes
        .iter()
        .map(|(id, m)| (*id, m.label()))
        .collect();
    for (id, label) in modes {
        match table.get(&(id, label)) {
            Some(comp) => {
                plan.compensation.insert(id, comp.clone());
            }
            None => {
                plan.compensation.remove(&id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist_uniform(bits: u32) -> LayerHist {
        let mut h = LayerHist::new(7, bits);
        let qmax = quant::qmax_for(bits);
        for c in h.counts.iter_mut() {
            *c = 1;
        }
        h.total = (2 * qmax + 1) as u64;
        h
    }

    #[test]
    fn exact_modes_have_no_error_fn() {
        assert!(mode_error_fn(&LayerMode::Fp32).unwrap().is_none());
        assert!(mode_error_fn(&LayerMode::lut("exact8")).unwrap().is_none());
        assert!(mode_error_fn(&LayerMode::ApproxFunc { bits: 12, trunc_k: 0 })
            .unwrap()
            .is_none());
        assert!(mode_error_fn(&LayerMode::lut("mitchell8")).unwrap().is_some());
        assert!(mode_error_fn(&LayerMode::ApproxFunc { bits: 12, trunc_k: 4 })
            .unwrap()
            .is_some());
    }

    #[test]
    fn rowsum_matches_bruteforce_mean() {
        let (err, bits) = mode_error_fn(&LayerMode::lut("drum8_4")).unwrap().unwrap();
        let hist = hist_uniform(bits);
        let row = rowsum_err(&hist, err.as_ref());
        let qmax = quant::qmax_for(bits) as i64;
        for &b in &[-qmax, -3, 0, 7, qmax] {
            let mut sum = 0.0f64;
            for a in -qmax..=qmax {
                sum += err(a, b) as f64;
            }
            let mean = sum / (2 * qmax + 1) as f64;
            let got = row[(b + qmax) as usize];
            assert!(
                (got - mean).abs() < 1e-9,
                "rowsum[{b}] = {got}, brute force {mean}"
            );
        }
    }

    #[test]
    fn needed_bits_dedups_and_skips_fp32() {
        let modes = [
            LayerMode::Fp32,
            LayerMode::lut("mitchell8"),
            LayerMode::lut("drum8_6"),
            LayerMode::ApproxFunc { bits: 12, trunc_k: 4 },
        ];
        assert_eq!(needed_bits(modes.iter()).unwrap(), vec![8, 12]);
    }
}
