//! `adapt` — the AdaPT-RS coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's evaluation:
//!
//! ```text
//! adapt specs                         Table 1 (model params / MAC OPs)
//! adapt features                      Table 3 (functionality matrix)
//! adapt multipliers                   ACU library characterization (MAE/MRE/power)
//! adapt table2 [--models a,b] [--steps-scale S] [--acu NAME]
//! adapt table4 [--models a,b] [--eval-batches N] [--skip-baseline]
//! adapt ablation [--model NAME]       ACU accuracy/power sweep
//! adapt sensitivity --model NAME [--acus a,b] [--budget PTS] [--workers N]
//!       [--search greedy|mcts] [--evals N] [--retrain-leaves N]
//!       [--retrain-epochs N] [--compensate] [--json]
//!       per-layer ACU sweep + mixed-precision plan search
//!       (heterogeneous plans); the sweep runs on a persistent pool of
//!       `--workers` threads with a byte-identical plan at any count;
//!       --search mcts runs the UCT planner warm-started by greedy under
//!       an --evals fresh-evaluation budget (deterministic per --seed);
//!       --retrain-leaves N re-scores the top searched plans with a short
//!       QAT run; --retrain-epochs QAT-retrains the found plan in the
//!       same command; --json prints the machine-readable summary
//!       (search method + seed + eval budget in the header) to stdout;
//!       --compensate fits calibrated error-compensation terms for every
//!       (layer, ACU) candidate and scores/search with them stamped on
//! adapt compensate [--synthetic] [--model NAME] [--acu NAME | --spec S]
//!       [--calib-batches N] [--eval-batches N] [--floor FRAC]
//!       [--out plan.json] [--json]
//!       fit per-output-channel error-compensation terms for a plan
//!       (rust/src/compensate) and emit the compensated plan JSON;
//!       --synthetic runs artifact-free on the bundled tiny model and
//!       asserts compensation recovers >= --floor (default 0.5) of the
//!       accuracy the raw approximate plan lost vs the exact8 reference,
//!       at identical MAC-weighted power (the CI smoke)
//! adapt search [--synthetic] [--budget N] [--seed S] [--max-drop PTS]
//!       [--floor PCT] [--retrain-leaves N] [--compensate]
//!       [--out plan.json] [--json]
//!       MCTS mixed-ACU plan discovery (TransAxx-style). --synthetic
//!       searches the bundled tiny model artifact-free (the CI smoke):
//!       sweep -> greedy incumbent -> MCTS under a --budget of fresh
//!       plan evaluations, asserting the saved plan reloads bit-exactly
//!       and meets the accuracy floor (--floor PCT absolute, or
//!       base - --max-drop points). Without --synthetic, runs the full
//!       artifact pipeline (`adapt sensitivity --search mcts`). Plans
//!       carry `provenance: "mcts:<seed>/<budget>"` (`+comp` when
//!       compensated), which the serving PlanStore records as the version
//!       source on upload. --compensate searches with the calibrated
//!       correction table stamped on every candidate, then re-runs the
//!       pipeline uncompensated and asserts the compensated winner is
//!       strictly cheaper under the comp-aware cost model.
//! adapt retrain --model NAME (--plan-file F | --spec S) [--epochs N]
//!       [--lr LR] [--seed S] [--save] [--approx-backward ACU]
//!       emulator-native QAT retraining of any per-layer plan —
//!       artifact-free (no PJRT), deterministic at any ADAPT_THREADS;
//!       `--synthetic [--check-improved]` runs the bundled tiny-model
//!       demo end to end (the CI smoke); --approx-backward NAME (or env
//!       ADAPT_APPROX_BACKWARD) routes the backward pass's transpose
//!       GEMMs through the named approximate multiplier
//! adapt plan --model NAME [--spec "default=ACU,layer=ACU,head=fp32"]
//!       [--out FILE]                  build/inspect a per-layer plan JSON
//! adapt calibrate --model NAME [--calibrator max|percentile|mse|entropy]
//! adapt serve [--model NAME]... [--requests N] [--workers N]
//!       [--queue-depth D] [--listen ADDR] [--synthetic]
//!       [--addr-file PATH] [--max-conns N] [--idle-timeout-ms MS]
//!       [--event-loops N] [--dispatch-threads N]
//!       engine-pool serving: N dynamic-batching workers over one bounded
//!       request queue per model (submitters block when it fills).
//!       Without --listen, the self-feeding demo; with --listen HOST:PORT
//!       (port 0 = ephemeral), the HTTP/1.1 front-end until killed: the
//!       /v1 single-model routes (a shim over the registry's default
//!       model) plus the /v2 registry routes (GET /v2/models,
//!       per-model infer/stats, immutable plan versions, canary, shadow,
//!       activate/rollback). --model may repeat: every name becomes a
//!       registry model with its own engine pool (the first is the /v1
//!       default). --synthetic serves bundled tiny models on the
//!       artifact-free emulator backend, one per name with distinct
//!       weights (the CI smoke); --addr-file writes the bound address
//!       for scripts. The front-end is a readiness loop: --event-loops
//!       event threads (default ADAPT_THREADS) multiplex every
//!       connection over epoll (Linux) or poll (forced via
//!       ADAPT_NET=poll), and --dispatch-threads (default
//!       2x ADAPT_THREADS, min 8) run the blocking engine waits.
//! adapt client --addr HOST:PORT [--model NAME] [--requests N]
//!       [--concurrency C] [--top-k K] [--deadline-ms D]
//!       [--swap-spec S | --swap-plan F] [--canary FRACTION] [--shadow]
//!       [--promote] [--bench-out FILE] [--json]
//!       load generator against a running `adapt serve --listen`:
//!       submit -> measure -> (optional plan rollout) -> measure -> show
//!       stats. --concurrency C keep-alive connections are multiplexed
//!       over a bounded worker pool, so thousands of connections are
//!       runnable from modest hardware. Default rollout is the v1-style create-and-activate
//!       swap; --canary F creates the version and routes fraction F to
//!       it instead (asserting the split), --shadow mirrors traffic to
//!       it and prints live disagreement stats, --promote activates the
//!       candidate after phase 2. --model targets a registry model
//!       (/v2 routes); --json emits the machine-readable report to
//!       stdout. Exits non-zero on any failed response or a rollout
//!       that doesn't take.
//! adapt profile [--spec S] [--batches N] [--batch B] [--threads T]
//!       [--out FILE]
//!       per-layer kernel cost table: run N batches of a plan through
//!       the emulator executor with the layer profiler on, print each
//!       layer's op / SIMD tier / product backend (LUT vs closed-form)
//!       / MACs / mean ns, and save the JSON cost model with --out.
//!       Artifact-free (profiles the bundled tiny model).
//! adapt selftest                      emulator vs XLA cross-check
//! ```
//!
//! Artifacts are searched in `./artifacts` (override: `--artifacts PATH`
//! or env `ADAPT_ARTIFACTS`). Thread defaults (`--workers`, `--threads`)
//! come from env `ADAPT_THREADS`, falling back to the machine's available
//! parallelism.
//!
//! Observability (all off by default, zero hot-path cost when off):
//!
//! * `ADAPT_TRACE_SAMPLE=0..=1` — tail-sampling rate for request traces
//!   (errors are always kept). Sampled traces are served at
//!   `GET /v1/trace/{id}` and `GET /v2/models/{m}/traces`.
//! * `ADAPT_PROFILE=1` — attach an enabled per-layer profiler to every
//!   engine worker (`adapt profile` is the offline equivalent).
//! * `ADAPT_LOG=warn|info|debug` (+ `ADAPT_LOG_JSON=1`) — leveled
//!   key=value (or JSON) diagnostics on stderr.
//! * `GET /metrics` — Prometheus text: engine counters + latency
//!   histograms, net-layer lifecycle counters, rollout gauges.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use adapt::coordinator::engine::{EmulatorSpec, EngineConfig, InferenceEngine, DEFAULT_QUEUE_DEPTH};
use adapt::coordinator::experiments::{self, SensitivityConfig, Table2Config, Table4Config};
use adapt::coordinator::features;
use adapt::coordinator::ops::{self, InferVariant};
use adapt::data::Sizes;
use adapt::emulator::{Executor, Style, Value};
use adapt::graph::{retransform, ExecutionPlan, LayerMode, Manifest, Policy};
use adapt::lut::LutRegistry;
use adapt::mult;
use adapt::quant::calib::CalibratorKind;
use adapt::runtime::Runtime;
use adapt::service::http::{HttpServer, ServeOptions};
use adapt::service::{client, AdaptService, ModelRegistry};
use adapt::util::cli::Args;
use adapt::util::fmt;
use adapt::util::json::Json;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn sizes_from(args: &Args) -> Result<Sizes> {
    Ok(Sizes {
        n_train: args.get_usize("train-samples", Sizes::default().n_train)?,
        n_eval: args.get_usize("eval-samples", Sizes::default().n_eval)?,
    })
}

fn artifacts_from(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(adapt::artifacts_dir)
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    // The progress diagnostics behind --verbose now go through the
    // leveled logger at info; honor the flag unless the user already
    // chose a level explicitly (must happen before the first log call
    // latches the config).
    if args.flag("verbose") && std::env::var_os("ADAPT_LOG").is_none() {
        std::env::set_var("ADAPT_LOG", "info");
    }
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "specs" => {
            let rt = Runtime::open(&artifacts_from(&args))?;
            println!("Table 1 — DNN specifications (per sample)\n");
            println!("{}", experiments::table1(&rt));
        }
        "features" => {
            println!("Table 3 — functionality vs state of the art\n");
            println!("{}", features::table3());
        }
        "multipliers" => {
            let samples = args.get_usize("samples", 2_000_000)?;
            println!("ACU library characterization (8-bit exhaustive, 12-bit sampled)\n");
            let mut rows = Vec::new();
            for (_, p) in mult::characterize_all(samples) {
                rows.push(vec![
                    p.name.clone(),
                    format!("{}b", p.bits),
                    format!("{:.5}%", p.mae_pct),
                    format!("{:.5}%", p.mre_pct),
                    format!("{}", p.wce),
                    format!("{:.2}x", p.power),
                ]);
            }
            println!(
                "{}",
                fmt::table(&["ACU", "bits", "MAE", "MRE", "WCE", "power"], &rows)
            );
        }
        "table2" => {
            let mut rt = Runtime::open(&artifacts_from(&args))?;
            let cfg = Table2Config {
                models: args.get_list("models"),
                sizes: sizes_from(&args)?,
                calibrator: CalibratorKind::parse(args.get_or("calibrator", "percentile"))
                    .context("bad --calibrator")?,
                percentile: args.get_f32("percentile", 0.999)? as f64,
                calib_batches: args.get_usize("calib-batches", 2)?,
                eval_batches: args.get("eval-batches").map(|s| s.parse()).transpose()?,
                steps_scale: args.get_f32("steps-scale", 1.0)? as f64,
                acu8: args.get_or("acu", "mul8s_1l2h_like").to_string(),
                verbose: args.flag("verbose"),
            };
            println!("Table 2 — accuracy per quantization technique + retraining\n");
            println!("{}", experiments::table2(&mut rt, &cfg)?);
        }
        "table4" => {
            let mut rt = Runtime::open(&artifacts_from(&args))?;
            let cfg = Table4Config {
                models: args.get_list("models"),
                sizes: sizes_from(&args)?,
                eval_batches: args.get_usize("eval-batches", 2)?,
                acu: args.get_or("acu", "mul8s_1l2h_like").to_string(),
                skip_baseline: args.flag("skip-baseline"),
                threads: args.get_usize("threads", adapt::util::threadpool::default_threads())?,
                verbose: args.flag("verbose"),
            };
            println!("Table 4 — inference emulation wall-clock\n");
            println!("{}", experiments::table4(&mut rt, &cfg)?);
        }
        "ablation" => {
            let mut rt = Runtime::open(&artifacts_from(&args))?;
            let model = args.get_or("model", "small_vgg").to_string();
            let eval_batches = args.get("eval-batches").map(|s| s.parse()).transpose()?;
            println!("ACU ablation on {model}\n");
            println!(
                "{}",
                experiments::ablation(&mut rt, &model, &sizes_from(&args)?, eval_batches)?
            );
        }
        "sensitivity" => {
            let mut rt = Runtime::open(&artifacts_from(&args))?;
            let defaults = SensitivityConfig::default();
            let cfg = SensitivityConfig {
                model: args.get_or("model", "small_vgg").to_string(),
                sizes: sizes_from(&args)?,
                eval_batches: args.get_usize("eval-batches", defaults.eval_batches)?,
                acus: {
                    let list = args.get_list("acus");
                    if list.is_empty() {
                        defaults.acus
                    } else {
                        list
                    }
                },
                reference: args.get_or("reference", "exact8").to_string(),
                // --budget is in accuracy points (e.g. 2.0 = two points).
                budget: args.get_f64("budget", 100.0 * defaults.budget)? / 100.0,
                threads: args.get_usize("threads", defaults.threads)?,
                sweep_workers: args.get_usize("workers", defaults.sweep_workers)?,
                retrain_epochs: args.get_usize("retrain-epochs", defaults.retrain_epochs)?,
                retrain_lr: args.get_f32("retrain-lr", defaults.retrain_lr)?,
                seed: args.get_usize("seed", defaults.seed as usize)? as u64,
                search: adapt::search::SearchMethod::parse(args.get_or("search", "greedy"))?,
                search_evals: args.get_usize("evals", defaults.search_evals)?,
                retrain_leaves: args.get_usize("retrain-leaves", defaults.retrain_leaves)?,
                compensate: args.flag("compensate"),
                verbose: args.flag("verbose"),
            };
            let json_mode = args.flag("json");
            // With --json, stdout carries exactly one JSON document; the
            // human report moves to stderr (same contract as `adapt client`).
            let say = |line: &str| {
                if json_mode {
                    eprintln!("{line}");
                } else {
                    println!("{line}");
                }
            };
            say("Per-layer ACU sensitivity + mixed-precision plan search\n");
            let outcome = experiments::layer_sensitivity(&mut rt, &cfg)?;
            say(&outcome.report);
            if json_mode {
                println!("{}", outcome.json.to_string());
            }
        }
        "retrain" => {
            let epochs = args.get_usize("epochs", 2)?;
            let threads =
                args.get_usize("threads", adapt::util::threadpool::default_threads())?;
            let seed = args.get_usize("seed", 0x5EED)? as u64;
            // --approx-backward NAME routes the QAT backward pass's
            // transpose GEMMs through the named ACU (paper §"approximate-
            // aware retraining"); also settable via ADAPT_APPROX_BACKWARD.
            let approx = args
                .get("approx-backward")
                .map(adapt::trainer::ApproxGrad::from_acu)
                .transpose()
                .context("bad --approx-backward")?;
            if args.flag("synthetic") {
                // Bundled tiny-model demo: pre-train -> calibrate ->
                // damage with a mixed-ACU plan -> QAT-retrain. Fully
                // in-memory (no artifacts dir at all) — the CI smoke.
                let lr = args.get_f32("lr", 0.004)?;
                let demo =
                    adapt::trainer::synth::demo_retrain_with(epochs, lr, seed, threads, approx)?;
                println!("{}", demo.report);
                if args.flag("check-improved") {
                    let (first, last) = demo.fit.improvement();
                    if !last.is_finite() || last >= first {
                        bail!(
                            "retrain smoke: loss did not decrease ({first:.4} -> {last:.4})"
                        );
                    }
                    println!("retrain smoke OK: loss {first:.4} -> {last:.4}");
                }
            } else {
                // Artifact-free path: manifest + weights blob + the Rust
                // engines; calibration runs on the emulator's fp32 taps.
                let manifest = Manifest::load(&artifacts_from(&args))?;
                let name = args.get_or("model", "small_vgg").to_string();
                let model = manifest.model(&name)?;
                let plan = match args.get("plan-file") {
                    Some(path) => {
                        let text = std::fs::read_to_string(path)
                            .with_context(|| format!("reading plan {path}"))?;
                        ExecutionPlan::from_json(&text, model)?
                    }
                    None => {
                        let spec = args.get_or("spec", "default=mul8s_1l2h_like");
                        let policy = Policy::parse_spec(spec)?;
                        let unmatched = policy.unmatched_overrides(model);
                        if !unmatched.is_empty() {
                            bail!("--spec overrides match no layer of {name}: {unmatched:?}");
                        }
                        retransform(model, &policy)
                    }
                };
                let cfg = experiments::RetrainConfig {
                    model: name,
                    sizes: sizes_from(&args)?,
                    epochs,
                    lr: args.get_f32("lr", 0.001)?,
                    momentum: args.get_f32("momentum", 0.9)?,
                    batch: args.get("batch").map(|s| s.parse()).transpose()?,
                    seed,
                    threads,
                    eval_batches: args.get_usize("eval-batches", 4)?,
                    save: args.flag("save"),
                    approx_backward: args.get("approx-backward").map(|s| s.to_string()),
                    verbose: args.flag("verbose"),
                };
                println!("Emulator-native QAT retraining (artifact-free)\n");
                println!("{}", experiments::retrain_plan(&manifest, &plan, &cfg)?);
            }
        }
        "plan" => {
            // Pure re-transform tooling: needs the manifest, not PJRT.
            let manifest = Manifest::load(&artifacts_from(&args))?;
            let name = args.get_or("model", "small_vgg").to_string();
            let model = manifest.model(&name)?;
            let plan = match args.get("plan-file") {
                Some(path) => {
                    let text = std::fs::read_to_string(path)
                        .with_context(|| format!("reading plan {path}"))?;
                    ExecutionPlan::from_json(&text, model)?
                }
                None => {
                    let spec = args.get_or("spec", "default=mul8s_1l2h_like");
                    let policy = Policy::parse_spec(spec)?;
                    // Typo guard: an override naming no layer would be
                    // silently dropped by retransform — fail loudly instead.
                    let unmatched = policy.unmatched_overrides(model);
                    if !unmatched.is_empty() {
                        let layers: Vec<&str> = model
                            .nodes
                            .iter()
                            .filter_map(|n| n.op.layer_name())
                            .collect();
                        bail!(
                            "--spec overrides match no layer of {name}: {unmatched:?} \
                             (quantizable layers: {})",
                            layers.join(", ")
                        );
                    }
                    retransform(model, &policy)
                }
            };
            // Validate every named ACU resolves (artifact or behavioral).
            let luts = LutRegistry::from_manifest(&manifest);
            luts.preload(&plan.acus())?;
            println!("plan for {name}:");
            print!("{}", plan.describe(model));
            if let Some(out) = args.get("out") {
                std::fs::write(out, plan.to_json(model))
                    .with_context(|| format!("writing {out}"))?;
                println!("written to {out}");
            }
        }
        "calibrate" => {
            let mut rt = Runtime::open(&artifacts_from(&args))?;
            let model = args.get("model").context("--model required")?.to_string();
            let kind = CalibratorKind::parse(args.get_or("calibrator", "percentile"))
                .context("bad --calibrator")?;
            let sizes = sizes_from(&args)?;
            let mut st = experiments::ensure_pretrained(&mut rt, &model, &sizes, 1.0, true)?;
            let ds = adapt::data::load(&st.model.dataset.clone(), &sizes);
            let batches = args.get_usize("calib-batches", 2)?;
            let scales = ops::calibrate(
                &mut rt,
                &mut st,
                &ds,
                batches,
                kind,
                args.get_f32("percentile", 0.999)? as f64,
            )?;
            println!("calibrated {model} with {kind:?} over {batches} batches:");
            for (i, s) in scales.iter().enumerate() {
                println!("  scale[{i:>2}] = {s:.6}  (calib_max = {:.4})", s * 127.0);
            }
        }
        "compensate" => compensate_cmd(&args)?,
        "search" => search_cmd(&args)?,
        "serve" => serve(&args)?,
        "client" => client_cmd(&args)?,
        "profile" => profile_cmd(&args)?,
        "selftest" => {
            let mut rt = Runtime::open(&artifacts_from(&args))?;
            let model = args.get_or("model", "small_vgg").to_string();
            selftest(&mut rt, &model)?;
        }
        _ => {
            println!("adapt — AdaPT-RS coordinator. See `rust/src/main.rs` docs for subcommands.");
            println!("  specs | features | multipliers | table2 | table4 | ablation");
            println!("  sensitivity --model M [--acus a,b] [--budget PTS] [--workers N]");
            println!("              [--search greedy|mcts] [--evals N] [--retrain-leaves N]");
            println!("              [--retrain-epochs N] [--compensate] [--json]");
            println!("  search [--synthetic] [--budget N] [--seed S] [--max-drop PTS] [--floor PCT]");
            println!("         [--retrain-leaves N] [--compensate] [--out plan.json] [--json]");
            println!("         (MCTS mixed-ACU plan discovery; --synthetic = artifact-free CI smoke;");
            println!("          --compensate = search with calibrated error-compensation stamped)");
            println!("  compensate [--synthetic] [--model M] [--acu NAME | --spec S] [--floor FRAC]");
            println!("             [--out plan.json] [--json]");
            println!("             (fit per-channel error-compensation terms, emit compensated plan;");
            println!("              --synthetic asserts >= FRAC of the accuracy drop is recovered)");
            println!("  retrain --model M (--plan-file F | --spec S) [--epochs N] [--lr LR] [--save]");
            println!("          [--approx-backward ACU]");
            println!("          (emulator QAT, artifact-free; --synthetic = bundled tiny-model smoke;");
            println!("           --approx-backward / ADAPT_APPROX_BACKWARD = approximate gradient GEMMs)");
            println!("  plan --model M [--spec S] | calibrate --model M");
            println!("  serve [--model M]... [--workers N] [--queue-depth D] [--listen ADDR] [--synthetic]");
            println!("        [--event-loops N] [--dispatch-threads N]");
            println!("        (--listen = HTTP/1.1 front-end: /v1 shim + /v2 registry routes on a");
            println!("         readiness loop — epoll on Linux, ADAPT_NET=poll to force poll(2);");
            println!("         repeat --model to serve several models, first = /v1 default)");
            println!("  client --addr HOST:PORT [--model M] [--requests N] [--concurrency C]");
            println!("         [--swap-spec S] [--canary F] [--shadow] [--promote] [--json]");
            println!("  profile [--spec S] [--batches N] [--batch B] [--out FILE]");
            println!("          (per-layer kernel cost table on the emulator; artifact-free)");
            println!("  selftest [--model M]");
            println!("  thread defaults: env ADAPT_THREADS (else available parallelism)");
            println!("  observability: ADAPT_TRACE_SAMPLE=0..1, ADAPT_PROFILE=1,");
            println!("                 ADAPT_LOG=warn|info|debug (ADAPT_LOG_JSON=1), GET /metrics");
        }
    }
    Ok(())
}

/// Deterministic per-name seed perturbation, so every named synthetic
/// model gets visibly distinct weights (FNV-1a over the name).
fn name_seed(base: u64, name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0100_0000_01b3);
    }
    base ^ h
}

/// `adapt serve`: start one engine pool per `--model` and either run the
/// self-feeding demo (no `--listen`) or expose the HTTP/1.1 front-end
/// (the /v1 shim + /v2 registry routes) until killed.
fn serve(args: &Args) -> Result<()> {
    let n = args.get_usize("requests", 64)?;
    let workers = args.get_usize("workers", adapt::util::threadpool::default_threads())?;
    let queue_depth = args.get_usize("queue-depth", DEFAULT_QUEUE_DEPTH)?;
    let max_wait = Duration::from_millis(args.get_usize("max-wait-ms", 20)? as u64);
    let acu = args.get_or("acu", "mul8s_1l2h_like").to_string();
    let synthetic = args.flag("synthetic");
    let base_seed = args.get_usize("seed", 0x5EED)? as u64;
    let batch = args.get_usize("batch", 8)?;

    // Engine config for one served name (`None` = the historical
    // single-model defaults, byte-compatible with the old CLI).
    let build_cfg = |name: Option<&str>| -> Result<(EngineConfig, String)> {
        let mut cfg = if synthetic {
            // Bundled tiny model on the artifact-free emulator backend:
            // no artifacts dir at all (the CI serve smoke). Named models
            // get name-perturbed weights so two registry models disagree.
            let mut model = adapt::trainer::synth::tiny_cnn();
            let seed = match name {
                Some(n) => {
                    model.name = n.to_string();
                    name_seed(base_seed, n)
                }
                None => base_seed,
            };
            let params = adapt::trainer::synth::tiny_params(&model, seed);
            let ds = adapt::trainer::synth::tiny_dataset(256, 64);
            let scales = adapt::trainer::calibrate_emulator(
                &model,
                &params,
                &ds.train,
                32,
                2,
                CalibratorKind::Percentile,
                0.999,
                workers.max(1),
            )?;
            let plan = retransform(&model, &Policy::all(LayerMode::lut(acu.as_str())));
            let spec = EmulatorSpec {
                model,
                params,
                plan,
                act_scales: scales,
                luts: LutRegistry::in_memory(),
                batch,
                gemm_threads: 1,
            };
            EngineConfig::emulator(spec)
        } else {
            let model = name.unwrap_or("small_vgg").to_string();
            EngineConfig::pjrt(
                artifacts_from(args),
                model,
                InferVariant::ApproxLut,
                Some(acu.clone()),
            )
        };
        cfg.max_wait = max_wait;
        cfg.workers = workers;
        cfg.queue_depth = queue_depth;
        let model_name = match &cfg.backend {
            adapt::coordinator::engine::BackendSpec::Pjrt { model, .. } => model.clone(),
            adapt::coordinator::engine::BackendSpec::Emulator(spec) => spec.model.name.clone(),
        };
        Ok((cfg, model_name))
    };

    let names: Vec<Option<String>> = {
        let given = args.get_all("model");
        if given.is_empty() {
            vec![None]
        } else {
            given.into_iter().map(Some).collect()
        }
    };

    if let Some(addr) = args.get("listen") {
        // Network front-end: one engine pool per model, one registry,
        // served until the process is killed.
        let mut entries = Vec::with_capacity(names.len());
        for name in &names {
            let (cfg, model_name) = build_cfg(name.as_deref())?;
            entries.push((model_name, std::sync::Arc::new(AdaptService::start(cfg)?)));
        }
        let served: Vec<String> = entries.iter().map(|(n, _)| n.clone()).collect();
        let registry = std::sync::Arc::new(ModelRegistry::new(entries)?);
        let opts = ServeOptions {
            max_conns: args.get_usize("max-conns", ServeOptions::default().max_conns)?,
            idle_timeout: Duration::from_millis(args.get_usize(
                "idle-timeout-ms",
                ServeOptions::default().idle_timeout.as_millis() as usize,
            )? as u64),
            event_loops: args.get_usize("event-loops", 0)?,
            dispatch_threads: args.get_usize("dispatch-threads", 0)?,
            ..ServeOptions::default()
        };
        let server = HttpServer::start_registry(registry, addr, opts)?;
        let bound = server.addr();
        println!(
            "adapt registry [{}] listening on http://{bound} \
             ({workers} workers/model, queue depth {queue_depth}, {} readiness loop)",
            served.join(", "),
            server.backend().name(),
        );
        println!("  POST /v1/infer   POST /v1/plan   GET /v1/stats   GET /v1/healthz");
        println!("  GET /v2/models   /v2/models/{{m}}/infer|stats|plans|traces|rollback");
        println!("  /v2/models/{{m}}/plans/{{v}}/activate|canary|shadow");
        println!("  GET /metrics (Prometheus)   GET /v1/trace/{{id}} (ADAPT_TRACE_SAMPLE)");
        if let Some(path) = args.get("addr-file") {
            std::fs::write(path, bound.to_string())
                .with_context(|| format!("writing {path}"))?;
        }
        loop {
            std::thread::park();
        }
    }

    // The self-feeding demo drives exactly one engine pool; serving
    // several models needs the HTTP registry.
    if names.len() > 1 {
        bail!("multiple --model flags need --listen (the registry front-end)");
    }
    let (cfg, model_name) = build_cfg(names[0].as_deref())?;

    // Self-feeding demo: build the request feed from the eval split (the
    // HTTP path above never needs it). i32-input models (token sequences)
    // ride along as rounded ids instead of refusing to start.
    let samples: Vec<Vec<f32>> = if synthetic {
        let ds = adapt::trainer::synth::tiny_dataset(64, 64);
        let per: usize = adapt::trainer::synth::tiny_cnn()
            .input_shape
            .iter()
            .product();
        (0..n.max(1))
            .map(|i| ds.eval.x_f[(i % ds.eval.num) * per..][..per].to_vec())
            .collect()
    } else {
        let rt = Runtime::open(&artifacts_from(args))?;
        let m = rt.manifest.model(&model_name)?;
        let ds = adapt::data::load(&m.dataset, &Sizes::small());
        let per: usize = m.input_shape.iter().product();
        let is_i32 = m.input_dtype == "i32";
        drop(rt);
        (0..n.max(1))
            .map(|i| {
                let at = (i % ds.eval.num) * per;
                if is_i32 {
                    ds.eval.x_i[at..at + per].iter().map(|&v| v as f32).collect()
                } else {
                    ds.eval.x_f[at..at + per].to_vec()
                }
            })
            .collect()
    };

    // The demo drives the legacy shim surface (`submit`/`infer` keep
    // working unchanged on top of the typed path).
    println!(
        "starting engine pool for {model_name} \
         ({workers} workers, queue depth {queue_depth}, {n} requests)..."
    );
    let engine = InferenceEngine::start(cfg)?;
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for x in samples.into_iter().take(n) {
        pending.push(engine.submit(x)?);
    }
    // Mid-run visibility: the pool reports progress *before* shutdown now.
    let snap = engine.stats_snapshot();
    println!(
        "mid-run snapshot: {} requests across {} batches so far (queue depth {})",
        snap.total.requests,
        snap.total.batches,
        engine.queue_len(),
    );
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let stats = engine.shutdown()?;
    let (qp50, qp95, qp99) = stats.queue_wait_percentiles_us();
    let (cp50, cp95, cp99) = stats.compute_percentiles_us();
    println!(
        "{ok}/{n} ok in {} ({:.1} req/s) — {} batches, {} padded slots, \
         queue wait {}, busy {}",
        fmt::dur(wall),
        n as f64 / wall.as_secs_f64(),
        stats.total.batches,
        stats.total.padded_slots,
        fmt::dur(stats.total.queue_wait),
        fmt::dur(stats.total.busy),
    );
    println!(
        "latency (µs): queue wait p50/p95/p99 = {qp50}/{qp95}/{qp99}, \
         compute p50/p95/p99 = {cp50}/{cp95}/{cp99}"
    );
    for (i, w) in stats.per_worker.iter().enumerate() {
        println!(
            "  worker {i}: {} requests, {} batches, {} padded, busy {}",
            w.requests,
            w.batches,
            w.padded_slots,
            fmt::dur(w.busy),
        );
    }
    Ok(())
}

/// How `adapt client` rolls the candidate plan out between its two
/// measured phases.
enum RolloutMode {
    /// v1-style create-and-activate swap (the default).
    Swap,
    /// Create the version and canary `fraction` of traffic to it.
    Canary(f64),
    /// Create the version and mirror traffic to it (shadow evaluation).
    Shadow,
}

/// `adapt client`: load-generate against a running `adapt serve --listen`,
/// optionally rolling a candidate plan out between two measured phases
/// (activate / canary / shadow, with `--promote` afterwards).
fn client_cmd(args: &Args) -> Result<()> {
    let addr = args.get("addr").context("--addr required (host:port)")?.to_string();
    let requests = args.get_usize("requests", 128)?;
    let concurrency = args.get_usize("concurrency", 4)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let model = args.get("model").map(|s| s.to_string());
    let json_mode = args.flag("json");
    // With --json, stdout carries exactly one JSON document; the human
    // narration moves to stderr.
    let say = |line: String| {
        if json_mode {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };
    let path = client::infer_path(model.as_deref());
    let input_len = match args.get_usize("input-len", 0)? {
        0 => match &model {
            Some(m) => client::discover_model_input_len(&addr, m)?,
            None => client::discover_input_len(&addr)?,
        },
        n => n,
    };
    let cfg = client::LoadConfig {
        addr: addr.clone(),
        requests,
        concurrency,
        input_len,
        top_k: args.get("top-k").map(|s| s.parse()).transpose()?,
        deadline_ms: args.get("deadline-ms").map(|s| s.parse()).transpose()?,
        seed,
    };
    say(format!(
        "load: {requests} requests x {concurrency} connections against http://{addr}{path} \
         (input_len {input_len})"
    ));
    // Server-side counters bracket each measured phase: a /metrics
    // scrape before and after gives the deltas (padding ratio, batch
    // counts, refusals) the BENCH records carry. Scrapes are best
    // effort — an old server without /metrics degrades to client-only
    // numbers instead of failing the run.
    let scrape = |label: &str| -> Option<std::collections::BTreeMap<String, f64>> {
        match client::scrape_metrics(&addr) {
            Ok(m) => Some(m),
            Err(e) => {
                say(format!("note: /metrics scrape {label} failed: {e:#}"));
                None
            }
        }
    };
    let m_start = scrape("before phase 1");
    let print_report = |label: &str, r: &client::LoadReport| {
        let gens: Vec<String> = r
            .by_generation
            .iter()
            .map(|(g, n)| format!("gen {g}: {n}"))
            .collect();
        let vers: Vec<String> = r
            .by_version
            .iter()
            .map(|(v, n)| format!("v{v}: {n}"))
            .collect();
        say(format!(
            "{label}: {}/{} ok in {} ({:.1} req/s), latency p50/p95/p99 = {}/{}/{} µs \
             [{}] [{}]",
            r.ok,
            r.ok + r.errors,
            fmt::dur(r.wall),
            r.requests_per_sec(),
            r.percentile_us(0.50),
            r.percentile_us(0.95),
            r.percentile_us(0.99),
            gens.join(", "),
            vers.join(", "),
        ));
    };
    let phase1 = client::run_load_on(&cfg, &path)?;
    let m_phase1 = scrape("after phase 1");
    print_report("phase 1", &phase1);
    if phase1.errors > 0 {
        bail!("{} failed responses in phase 1", phase1.errors);
    }
    let phase1_delta = match (&m_start, &m_phase1) {
        (Some(b), Some(a)) => Some(client::metrics_delta(b, a)),
        _ => None,
    };
    if let Some(d) = &phase1_delta {
        let padded = metric_sum(d, "adapt_padded_slots_total");
        let served = metric_sum(d, "adapt_requests_total");
        say(format!(
            "server deltas (phase 1): {served:.0} requests, {:.0} batches, \
             padding ratio {:.3}, {:.0} conns refused",
            metric_sum(d, "adapt_batches_total"),
            padded / (served + padded).max(1.0),
            metric_sum(d, "adapt_net_refused_total"),
        ));
    }

    // Optional rollout of a candidate plan between the two phases.
    let rollout = if let Some(f) = args.get("canary") {
        RolloutMode::Canary(f.parse().context("--canary takes a fraction in [0, 1]")?)
    } else if args.flag("shadow") {
        RolloutMode::Shadow
    } else {
        RolloutMode::Swap
    };
    let swap_body = if let Some(spec) = args.get("swap-spec") {
        let mut m = std::collections::BTreeMap::new();
        m.insert("spec".to_string(), Json::Str(spec.to_string()));
        Some(Json::Obj(m).to_string())
    } else {
        args.get("swap-plan")
            .map(|path| {
                std::fs::read_to_string(path).with_context(|| format!("reading plan {path}"))
            })
            .transpose()?
    };

    // A rollout mode without a candidate plan would silently measure
    // nothing — refuse instead.
    if swap_body.is_none() && !matches!(rollout, RolloutMode::Swap) {
        bail!("--canary/--shadow need a candidate plan (use --swap-spec or --swap-plan)");
    }

    // The /v2 routes need a model name; resolve the registry default
    // when the rollout needs them and --model wasn't given.
    let v2_target = |needed: bool| -> Result<Option<String>> {
        if let Some(m) = &model {
            return Ok(Some(m.clone()));
        }
        if !needed {
            return Ok(None);
        }
        let (status, body) = client::http_call(&addr, "GET", "/v2/models", None)?;
        if status != 200 {
            bail!("/v2/models failed ({status}): {body}");
        }
        Ok(Some(Json::parse(&body)?.get("default")?.str()?.to_string()))
    };

    let mut phase2: Option<(String, client::LoadReport)> = None;
    let mut phase2_delta: Option<Json> = None;
    let mut candidate: Option<(String, u64)> = None; // (target model, version)
    if let Some(body) = swap_body {
        let (label, expect_generation, expect_canary) = match &rollout {
            RolloutMode::Swap => {
                let generation = match &model {
                    // v1-compatible path: one call creates + activates
                    // on the default model.
                    None => {
                        let (status, resp) =
                            client::http_call(&addr, "POST", "/v1/plan", Some(&body))?;
                        if status != 200 {
                            bail!("plan swap failed ({status}): {resp}");
                        }
                        Json::parse(&resp)?.get("generation")?.i64()? as u64
                    }
                    // Targeted model: create the version, then activate.
                    Some(_) => {
                        let target = v2_target(true)?.expect("model given");
                        let version = create_candidate(&addr, &target, &body)?;
                        let (status, resp) = client::http_call(
                            &addr,
                            "POST",
                            &format!("/v2/models/{target}/plans/{version}/activate"),
                            Some("{}"),
                        )?;
                        if status != 200 {
                            bail!("activate failed ({status}): {resp}");
                        }
                        candidate = Some((target, version));
                        Json::parse(&resp)?.get("generation")?.i64()? as u64
                    }
                };
                say(format!("plan swapped: now serving generation {generation}"));
                ("phase 2 (swapped)", Some(generation), None)
            }
            RolloutMode::Canary(f) => {
                let fraction = *f;
                let target = v2_target(true)?.expect("resolved above");
                let version = create_candidate(&addr, &target, &body)?;
                let (status, resp) = client::http_call(
                    &addr,
                    "POST",
                    &format!("/v2/models/{target}/plans/{version}/canary"),
                    Some(&format!("{{\"fraction\": {fraction}}}")),
                )?;
                if status != 200 {
                    bail!("canary start failed ({status}): {resp}");
                }
                say(format!(
                    "canary: version {version} takes {:.1}% of {target} traffic",
                    fraction * 100.0
                ));
                candidate = Some((target, version));
                ("phase 2 (canary)", None, Some((version, fraction)))
            }
            RolloutMode::Shadow => {
                let target = v2_target(true)?.expect("resolved above");
                let version = create_candidate(&addr, &target, &body)?;
                let (status, resp) = client::http_call(
                    &addr,
                    "POST",
                    &format!("/v2/models/{target}/plans/{version}/shadow"),
                    Some("{}"),
                )?;
                if status != 200 {
                    bail!("shadow start failed ({status}): {resp}");
                }
                say(format!("shadow: mirroring {target} traffic to version {version}"));
                candidate = Some((target, version));
                ("phase 2 (shadowed)", None, None)
            }
        };

        let cfg2 = client::LoadConfig {
            seed: seed ^ 0xA5A5,
            ..cfg.clone()
        };
        let r = client::run_load_on(&cfg2, &path)?;
        let m_phase2 = scrape("after phase 2");
        phase2_delta = match (&m_phase1, &m_phase2) {
            (Some(b), Some(a)) => Some(client::metrics_delta(b, a)),
            _ => None,
        };
        print_report(label, &r);
        if r.errors > 0 {
            bail!("{} failed responses in phase 2", r.errors);
        }
        if let Some(generation) = expect_generation {
            // Every phase-2 response was submitted after the swap
            // returned, so all of them must carry the new generation.
            if r.by_generation.keys().any(|&g| g != generation) {
                bail!(
                    "phase 2 saw generations {:?}, expected only {generation}",
                    r.by_generation.keys().collect::<Vec<_>>()
                );
            }
        }
        if let Some((version, fraction)) = expect_canary {
            // The counter-based split is deterministic: exactly
            // ⌊n·fraction⌋ of the n phase-2 requests hit the candidate.
            let got = r.by_version.get(&version).copied().unwrap_or(0);
            let want = (requests as f64 * fraction).floor() as usize;
            if got != want {
                bail!(
                    "canary split off: {got}/{requests} responses on version {version}, \
                     expected exactly {want}"
                );
            }
            say(format!(
                "canary split exact: {got}/{requests} responses on version {version}"
            ));
        }
        if matches!(rollout, RolloutMode::Shadow) {
            let (target, version) = candidate.clone().expect("shadow set candidate");
            let report = client::wait_shadow_report(
                &addr,
                &target,
                version,
                requests,
                Duration::from_secs(30),
            )?;
            say(format!(
                "shadow report v{version}: {} mirrored, disagreement {:.1}%, \
                 top-1 flips {:.1}%, max |Δ| {:.3e}",
                report.get("mirrored")?.i64()?,
                report.get("disagreement_rate")?.f64()? * 100.0,
                report.get("top1_flip_rate")?.f64()? * 100.0,
                report.get("max_abs_delta")?.f64()?,
            ));
        }
        phase2 = Some((label.to_string(), r));
    }

    // Promote the candidate after the measured phases, if asked.
    if args.flag("promote") {
        let (target, version) = candidate
            .clone()
            .context("--promote needs a candidate (use --swap-spec/--swap-plan)")?;
        let (status, resp) = client::http_call(
            &addr,
            "POST",
            &format!("/v2/models/{target}/plans/{version}/activate"),
            Some("{}"),
        )?;
        if status != 200 {
            bail!("promote failed ({status}): {resp}");
        }
        say(format!(
            "promoted: {target} now serves version {version} (generation {})",
            Json::parse(&resp)?.get("generation")?.i64()?,
        ));
    }

    // Server-side stats: the targeted model's /v2 view, or /v1.
    let stats_path = match &model {
        Some(m) => format!("/v2/models/{m}/stats"),
        None => "/v1/stats".to_string(),
    };
    let (status, stats) = client::http_call(&addr, "GET", &stats_path, None)?;
    if status != 200 {
        bail!("{stats_path} failed ({status}): {stats}");
    }
    let j = Json::parse(&stats)?;
    let total = j.get("total")?;
    say(format!(
        "server stats: {} requests, {} batches, generation {}, \
         queue wait p50/p95/p99 = {}/{}/{} µs",
        total.get("requests")?.i64()?,
        total.get("batches")?.i64()?,
        j.get("generation")?.i64()?,
        total.get("queue_wait_p50_us")?.i64()?,
        total.get("queue_wait_p95_us")?.i64()?,
        total.get("queue_wait_p99_us")?.i64()?,
    ));

    // The machine-readable report: --bench-out writes it, --json prints
    // it to stdout (same shape, so scripts can use either).
    if args.get("bench-out").is_some() || json_mode {
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("requests".to_string(), Json::Num(requests as f64));
        doc.insert("concurrency".to_string(), Json::Num(concurrency as f64));
        if let Some(m) = &model {
            doc.insert("model".to_string(), Json::Str(m.clone()));
        }
        doc.insert("phase1".to_string(), phase1.to_json());
        if let Some(d) = &phase1_delta {
            let padded = metric_sum(d, "adapt_padded_slots_total");
            let served = metric_sum(d, "adapt_requests_total");
            doc.insert(
                "phase1_padding_ratio".to_string(),
                Json::Num(padded / (served + padded).max(1.0)),
            );
            doc.insert(
                "phase1_refused_conns".to_string(),
                Json::Num(metric_sum(d, "adapt_net_refused_total")),
            );
            doc.insert("phase1_metrics_delta".to_string(), d.clone());
        }
        if let Some((label, r)) = &phase2 {
            doc.insert("phase2".to_string(), r.to_json());
            doc.insert("phase2_label".to_string(), Json::Str(label.clone()));
        }
        if let Some(d) = &phase2_delta {
            let padded = metric_sum(d, "adapt_padded_slots_total");
            let served = metric_sum(d, "adapt_requests_total");
            doc.insert(
                "phase2_padding_ratio".to_string(),
                Json::Num(padded / (served + padded).max(1.0)),
            );
            doc.insert(
                "phase2_refused_conns".to_string(),
                Json::Num(metric_sum(d, "adapt_net_refused_total")),
            );
            doc.insert("phase2_metrics_delta".to_string(), d.clone());
        }
        if let Some((target, version)) = &candidate {
            doc.insert("candidate_model".to_string(), Json::Str(target.clone()));
            doc.insert("candidate_version".to_string(), Json::Num(*version as f64));
        }
        doc.insert("server_stats".to_string(), j);
        let text = Json::Obj(doc).to_string();
        if let Some(out) = args.get("bench-out") {
            std::fs::write(out, &text).with_context(|| format!("writing {out}"))?;
            say(format!("written {out}"));
        }
        if json_mode {
            println!("{text}");
        }
    }
    Ok(())
}

/// Sum every series of one metric name (across label sets) in a
/// [`client::metrics_delta`] object.
fn metric_sum(delta: &Json, name: &str) -> f64 {
    let Json::Obj(m) = delta else {
        return 0.0;
    };
    let prefix = format!("{name}{{");
    m.iter()
        .filter(|(k, _)| k.as_str() == name || k.starts_with(&prefix))
        .filter_map(|(_, v)| v.f64().ok())
        .sum()
}

/// `adapt profile`: run N batches of a per-layer plan through the
/// emulator executor with the layer profiler on, print the per-layer
/// cost table in execution order, and optionally save the JSON cost
/// model. Artifact-free: profiles the bundled tiny model, so it runs
/// anywhere the CI smoke does.
fn profile_cmd(args: &Args) -> Result<()> {
    let batches = args.get_usize("batches", 16)?;
    let batch = args.get_usize("batch", 8)?;
    let threads = args.get_usize("threads", adapt::util::threadpool::default_threads())?;
    let seed = args.get_usize("seed", 0x5EED)? as u64;
    let spec = args.get_or("spec", "default=mul8s_1l2h_like").to_string();

    let model = adapt::trainer::synth::tiny_cnn();
    let params = adapt::trainer::synth::tiny_params(&model, seed);
    let ds = adapt::trainer::synth::tiny_dataset(256, (batches * batch).max(64));
    let scales = adapt::trainer::calibrate_emulator(
        &model,
        &params,
        &ds.train,
        32,
        2,
        CalibratorKind::Percentile,
        0.999,
        threads.max(1),
    )?;
    let policy = Policy::parse_spec(&spec)?;
    let plan = retransform(&model, &policy);
    let luts = LutRegistry::in_memory();
    let mut exec = Executor::new(
        &model,
        params,
        plan,
        scales,
        &luts,
        Style::Optimized { threads },
    )?;
    let profiler = std::sync::Arc::new(adapt::obs::LayerProfiler::new(true));
    exec.set_profiler(Some(std::sync::Arc::clone(&profiler)));

    let n_batches = ds.eval.n_batches(batch).max(1);
    let t0 = std::time::Instant::now();
    for i in 0..batches {
        let x = ds.eval.batch_tensor(i % n_batches, batch);
        exec.forward(Value::F(x))?;
    }
    let wall = t0.elapsed();

    let table = profiler.to_json();
    let layer_total_ns = table.get("layer_total_ns")?.f64()?;
    let mut rows = Vec::new();
    for layer in table.get("layers")?.arr()? {
        let total = layer.get("total_ns")?.f64()?;
        rows.push(vec![
            layer.get("name")?.str()?.to_string(),
            layer.get("op")?.str()?.to_string(),
            layer.get("tier")?.str()?.to_string(),
            layer.get("backend")?.str()?.to_string(),
            format!("{}", layer.get("bits")?.i64()?),
            format!("{}", layer.get("macs")?.i64()?),
            format!("{:.0}", layer.get("mean_ns")?.f64()?),
            format!("{:.1}%", 100.0 * total / layer_total_ns.max(1.0)),
        ]);
    }
    println!(
        "per-layer kernel profile: {} x batch {batch} on {} (spec {spec}, {threads} threads)\n",
        batches, model.name,
    );
    println!(
        "{}",
        fmt::table(
            &["layer", "op", "tier", "backend", "bits", "macs", "mean ns", "share"],
            &rows
        )
    );
    let coverage = layer_total_ns / (wall.as_nanos() as f64).max(1.0);
    println!(
        "layer-sum {} of {} measured forward wall ({:.1}% coverage)",
        fmt::dur(Duration::from_nanos(layer_total_ns as u64)),
        fmt::dur(wall),
        100.0 * coverage,
    );

    if let Some(out) = args.get("out") {
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("model".to_string(), Json::Str(model.name.clone()));
        doc.insert("spec".to_string(), Json::Str(spec));
        doc.insert("batches".to_string(), Json::Num(batches as f64));
        doc.insert("batch".to_string(), Json::Num(batch as f64));
        doc.insert("threads".to_string(), Json::Num(threads as f64));
        doc.insert(
            "wall_forward_ns".to_string(),
            Json::Num(wall.as_nanos() as f64),
        );
        doc.insert("profile".to_string(), table);
        std::fs::write(out, Json::Obj(doc).to_string())
            .with_context(|| format!("writing {out}"))?;
        println!("written {out}");
    }
    Ok(())
}

/// `adapt compensate`: fit the per-ACU error-compensation terms for a
/// plan and emit the compensated plan JSON. `--synthetic` runs the whole
/// flow artifact-free on the bundled tiny model (the CI smoke): pre-train,
/// calibrate activation histograms, stamp an aggressive single-ACU plan
/// with corrections, then assert the compensated plan recovers at least
/// `--floor` (default 0.5) of the accuracy the uncompensated plan lost
/// against the exact8 reference — at identical MAC-weighted power.
fn compensate_cmd(args: &Args) -> Result<()> {
    let threads = args.get_usize("threads", adapt::util::threadpool::default_threads())?;
    let seed = args.get_usize("seed", 0x5EED)? as u64;
    let acu = args.get_or("acu", "mitchell8").to_string();
    let calib_batches = args.get_usize("calib-batches", 2)?;
    let eval_batches = args.get_usize("eval-batches", 8)?;
    // Fraction of the accuracy drop compensation must win back.
    let floor = args.get_f64("floor", 0.5)?;
    let json_mode = args.flag("json");
    let say = |line: String| {
        if json_mode {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };
    let t0 = std::time::Instant::now();

    // Assemble (model, params, scales, dataset, luts, batch) from either
    // the bundled synthetic tiny model or the artifact manifest.
    let (model, params, scales, ds, luts, bs);
    if args.flag("synthetic") {
        let setup = adapt::trainer::synth::tiny_pretrained(seed, threads)?;
        model = setup.model;
        params = setup.params;
        scales = setup.scales;
        ds = setup.ds;
        luts = LutRegistry::in_memory();
        bs = 32usize;
    } else {
        let mut rt = Runtime::open(&artifacts_from(args))?;
        let name = args.get_or("model", "small_vgg").to_string();
        let sizes = sizes_from(args)?;
        let mut st = experiments::ensure_pretrained(&mut rt, &name, &sizes, 1.0, true)?;
        ds = adapt::data::load(&st.model.dataset.clone(), &sizes);
        scales = ops::calibrate(
            &mut rt,
            &mut st,
            &ds,
            calib_batches,
            CalibratorKind::Percentile,
            0.999,
        )?;
        model = st.model.clone();
        params = st.params_tensors()?;
        luts = LutRegistry::from_manifest(&rt.manifest);
        bs = rt.manifest.batch;
    }

    let plan = match args.get("spec") {
        Some(spec) => {
            let policy = Policy::parse_spec(spec)?;
            let unmatched = policy.unmatched_overrides(&model);
            if !unmatched.is_empty() {
                bail!("--spec overrides match no layer of {}: {unmatched:?}", model.name);
            }
            retransform(&model, &policy)
        }
        None => retransform(&model, &Policy::all(LayerMode::lut(acu.as_str()))),
    };
    luts.preload(&plan.acus())?;

    // Fit: activation histograms at every bitwidth the plan quantizes at,
    // then the per-output-channel correction for each approximated layer.
    let bits = adapt::compensate::needed_bits(plan.modes.values())?;
    let calib = adapt::compensate::collect(
        &model,
        &params,
        &ds.train,
        bs,
        calib_batches,
        &scales,
        &bits,
        threads.max(1),
    )?;
    let mut comp_plan = plan.clone();
    let applied =
        adapt::compensate::compensate_plan(&model, &params, &scales, &calib, &mut comp_plan)?;
    say(format!(
        "compensate: fitted {applied} layer correction(s) for plan [{}] \
         ({} histogram bitwidth(s))",
        plan.describe(&model).trim_end().replace('\n', "; "),
        bits.len(),
    ));

    // Score the exact reference, the raw approximate plan, and the
    // compensated twin on the same eval batches.
    let ref_plan = retransform(&model, &Policy::all(LayerMode::lut("exact8")));
    let eval = |p: &ExecutionPlan| {
        adapt::trainer::evaluate(
            &model,
            params.clone(),
            p,
            &scales,
            &luts,
            &ds.eval,
            bs,
            eval_batches,
            threads.max(1),
        )
    };
    let base = eval(&ref_plan)?;
    let uncomp = eval(&plan)?;
    let comp = eval(&comp_plan)?;
    let dropped = (base - uncomp).max(0.0);
    let recovered = if dropped <= 1e-9 { 1.0 } else { (comp - uncomp) / dropped };

    // The correction rides the bias epilogue: the MAC-weighted power of
    // the compensated twin is identical by construction; the comp-aware
    // model charges one add per output element on top.
    let macs = adapt::search::layer_macs(&model);
    let outs = adapt::search::layer_outputs(&model);
    let cost_plain = adapt::search::plan_cost_macs(&macs, &plan);
    let cost_comp_macs = adapt::search::plan_cost_macs(&macs, &comp_plan);
    anyhow::ensure!(
        cost_plain == cost_comp_macs,
        "compensation changed the MAC-weighted power: {cost_plain} vs {cost_comp_macs}"
    );
    let cost_comp = adapt::search::plan_cost_comp(&macs, &outs, &comp_plan);

    say(format!(
        "exact8 reference {} | uncompensated {} | compensated {} — recovered {:.1}% \
         of the drop (floor {:.1}%)",
        fmt::pct(base),
        fmt::pct(uncomp),
        fmt::pct(comp),
        100.0 * recovered,
        100.0 * floor,
    ));
    say(format!(
        "power: {cost_plain:.4}x MAC-weighted (unchanged), {cost_comp:.4}x with \
         compensation adds charged",
    ));
    anyhow::ensure!(
        recovered >= floor,
        "compensation recovered only {:.1}% of the {:.2}-point drop (floor {:.1}%)",
        100.0 * recovered,
        100.0 * dropped,
        100.0 * floor,
    );

    let provenance = format!("compensate:{acu}");
    if let Some(path) = args.get("out") {
        let plan_json = comp_plan.to_json_with(&model, Some(&provenance));
        let reloaded = ExecutionPlan::from_json(&plan_json, &model)?;
        anyhow::ensure!(reloaded == comp_plan, "compensated plan JSON did not round-trip");
        std::fs::write(path, &plan_json).with_context(|| format!("writing {path}"))?;
        say(format!("compensated plan written to {path} (provenance {provenance})"));
    }
    let wall = t0.elapsed();
    say(format!("compensate done in {}", fmt::dur(wall)));

    if json_mode {
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("model".to_string(), Json::Str(model.name.clone()));
        doc.insert("acu".to_string(), Json::Str(acu));
        doc.insert("compensated_layers".to_string(), Json::Num(applied as f64));
        doc.insert("base_accuracy".to_string(), Json::Num(base));
        doc.insert("uncompensated_accuracy".to_string(), Json::Num(uncomp));
        doc.insert("compensated_accuracy".to_string(), Json::Num(comp));
        doc.insert("recovered_frac".to_string(), Json::Num(recovered));
        doc.insert("floor".to_string(), Json::Num(floor));
        doc.insert("power".to_string(), Json::Num(cost_plain));
        doc.insert("comp_power".to_string(), Json::Num(cost_comp));
        doc.insert("provenance".to_string(), Json::Str(provenance));
        doc.insert("wall_s".to_string(), Json::Num(wall.as_secs_f64()));
        println!("{}", Json::Obj(doc).to_string());
    }
    Ok(())
}

/// `adapt search`: MCTS mixed-ACU plan discovery. `--synthetic` runs the
/// whole pipeline artifact-free on the bundled tiny model — calibrate,
/// sweep, greedy incumbent, MCTS under a fresh-evaluation budget — then
/// verifies the saved plan JSON reloads bit-exactly and meets the accuracy
/// floor (the CI smoke). Without `--synthetic` it is `adapt sensitivity
/// --search mcts` with the eval-budget flag mapped.
fn search_cmd(args: &Args) -> Result<()> {
    let evals = args.get_usize("budget", 48)?;
    let seed = args.get_usize("seed", 0x5EED)? as u64;
    let max_drop = args.get_f64("max-drop", 2.0)? / 100.0;
    let retrain_leaves = args.get_usize("retrain-leaves", 0)?;
    let retrain_epochs = args.get_usize("retrain-epochs", 1)?;
    let retrain_lr = args.get_f32("retrain-lr", 0.002)?;
    let workers = args.get_usize("workers", adapt::util::threadpool::default_threads())?;
    let threads = args.get_usize("threads", adapt::util::threadpool::default_threads())?;
    let reference = args.get_or("reference", "exact8").to_string();
    let compensate_on = args.flag("compensate");
    let acus: Vec<String> = {
        let list = args.get_list("acus");
        if list.is_empty() {
            let mut v = vec![
                "mul8s_1l2h_like".to_string(),
                "drum8_6".to_string(),
                "trunc_out8_4".to_string(),
            ];
            if compensate_on {
                // The cheapest, highest-error ACU in the registry —
                // exactly the candidate calibrated compensation unlocks.
                v.push("mitchell8".to_string());
            }
            v
        } else {
            list
        }
    };
    let json_mode = args.flag("json");
    let say = |line: String| {
        if json_mode {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };

    if !args.flag("synthetic") {
        // Artifact pipeline: the sensitivity harness with MCTS selected.
        let mut rt = Runtime::open(&artifacts_from(args))?;
        let defaults = SensitivityConfig::default();
        let cfg = SensitivityConfig {
            model: args.get_or("model", "small_vgg").to_string(),
            sizes: sizes_from(args)?,
            eval_batches: args.get_usize("eval-batches", defaults.eval_batches)?,
            acus,
            reference,
            budget: max_drop,
            threads,
            sweep_workers: workers,
            retrain_epochs: args.get_usize("retrain-epochs", 0)?,
            retrain_lr,
            seed,
            search: adapt::search::SearchMethod::Mcts,
            search_evals: evals,
            retrain_leaves,
            compensate: compensate_on,
            verbose: args.flag("verbose"),
        };
        say("MCTS mixed-ACU plan search\n".to_string());
        let outcome = experiments::layer_sensitivity(&mut rt, &cfg)?;
        say(outcome.report.clone());
        if let Some(out) = args.get("out") {
            std::fs::write(out, &outcome.plan_json)
                .with_context(|| format!("writing {out}"))?;
            say(format!("plan written to {out}"));
        }
        if json_mode {
            println!("{}", outcome.json.to_string());
        }
        return Ok(());
    }

    // ----- artifact-free synthetic pipeline (the CI smoke) ---------------
    use adapt::coordinator::experiments::{greedy_mixed, sweep_pairs, worst_drops, EvalBatch, SweepCtx};
    use adapt::search::mcts;

    let t0 = std::time::Instant::now();
    let model = adapt::trainer::synth::tiny_cnn();
    let params = adapt::trainer::synth::tiny_params(&model, 0x5EED);
    let ds = adapt::trainer::synth::tiny_dataset(256, 64);
    let scales = adapt::trainer::calibrate_emulator(
        &model,
        &params,
        &ds.train,
        32,
        2,
        CalibratorKind::Percentile,
        0.999,
        threads.max(1),
    )?;
    let bs = 32usize;
    let nb = args.get_usize("eval-batches", 2)?.max(1).min(ds.eval.n_batches(bs).max(1));
    // With --compensate, fit the (layer x candidate-ACU) correction table
    // once up front; the sweep context stamps every evaluated plan with it.
    let comp_table = if compensate_on {
        let cand: Vec<LayerMode> = acus.iter().map(|a| LayerMode::lut(a.as_str())).collect();
        let bits = adapt::compensate::needed_bits(cand.iter())?;
        let calib = adapt::compensate::collect(
            &model,
            &params,
            &ds.train,
            bs,
            2,
            &scales,
            &bits,
            threads.max(1),
        )?;
        let ids: Vec<usize> = adapt::search::layer_macs(&model).keys().copied().collect();
        Some(adapt::compensate::comp_table(&model, &params, &scales, &calib, &ids, &cand)?)
    } else {
        None
    };
    let mk_ctx = |comp: Option<adapt::compensate::CompTable>| {
        std::sync::Arc::new(SweepCtx {
            model: model.clone(),
            params: params.clone(),
            scales: scales.clone(),
            luts: LutRegistry::in_memory(),
            batches: (0..nb)
                .map(|bi| EvalBatch::from_split(&model, &ds.eval, bi, bs))
                .collect(),
            bs,
            gemm_threads: threads.max(1),
            comp,
        })
    };
    let ctx = mk_ctx(comp_table.clone());
    let layers = ctx.layers();
    let ref_plan = retransform(&ctx.model, &Policy::all(LayerMode::lut(reference.as_str())));
    let base_acc = ctx.eval_plan(ref_plan.clone())?;
    let floor = match args.get("floor") {
        Some(f) => f.parse::<f64>().context("--floor takes an absolute percent")? / 100.0,
        None => base_acc - max_drop,
    };
    let budget = (base_acc - floor).max(0.0);
    say(format!(
        "search --synthetic: {} layers, {} ACUs, base accuracy {}, floor {} \
         (budget {:.2} pts), {evals} evals, seed {seed:#x}",
        layers.len(),
        acus.len(),
        fmt::pct(base_acc),
        fmt::pct(floor),
        100.0 * budget,
    ));

    let pool = (workers > 1).then(|| adapt::util::threadpool::ThreadPool::new(workers));
    let pair_accs = sweep_pairs(&ctx, &ref_plan, &layers, &acus, pool.as_ref())?;
    let worst = worst_drops(base_acc, &pair_accs, layers.len(), acus.len());
    let (gplan, gacc, gevals) =
        greedy_mixed(&ctx, &ref_plan, &reference, base_acc, &layers, &worst, &acus, budget)?;

    let space = mcts::SearchSpace::build(
        &ctx.model,
        ref_plan.clone(),
        &reference,
        base_acc,
        budget,
        &layers,
        &pair_accs,
        &acus,
    )?;
    let greedy_reward = space.reward(gacc, &gplan);
    let greedy_savings = space.savings(&gplan);
    let mcfg = mcts::MctsConfig {
        seed,
        evals,
        ..mcts::MctsConfig::default()
    };
    let rc_store;
    let rc = if retrain_leaves > 0 {
        rc_store = mcts::RetrainCtx {
            train: &ds.train,
            leaves: retrain_leaves,
            epochs: retrain_epochs,
            lr: retrain_lr,
            seed,
        };
        Some(&rc_store)
    } else {
        None
    };
    let out = mcts::search(&ctx, space, &mcfg, Some((&gplan, gacc)), pool.as_ref(), rc)?;
    let wall = t0.elapsed();
    // The search scores plans with compensation stamped on the fly; the
    // emitted artifact must carry those terms explicitly.
    let mut best_plan = out.plan.clone();
    if let Some(table) = &comp_table {
        adapt::compensate::apply_table(table, &mut best_plan);
    }

    say(format!(
        "greedy:  accuracy {} ({} evals, savings {:.1}%)",
        fmt::pct(gacc),
        gevals,
        100.0 * greedy_savings,
    ));
    say(format!(
        "mcts:    accuracy {} ({} evals + {} cache hits, {} playouts, savings {:.1}%, \
         reward {:.4}{})",
        fmt::pct(out.accuracy),
        out.evals,
        out.cache_hits,
        out.playouts,
        100.0 * out.savings,
        out.reward,
        if out.retrained > 0 {
            format!(", {} leaves retrained", out.retrained)
        } else {
            String::new()
        },
    ));
    say(format!("selected plan:\n{}", best_plan.describe(&ctx.model)));

    // Hard guarantees the smoke asserts: the incumbent warm-start means
    // MCTS can never end up below greedy, and the winner must clear the
    // accuracy floor.
    anyhow::ensure!(
        out.reward >= greedy_reward,
        "mcts reward {} fell below greedy's {}",
        out.reward,
        greedy_reward
    );
    anyhow::ensure!(
        out.accuracy >= floor,
        "searched plan accuracy {} is below the floor {}",
        fmt::pct(out.accuracy),
        fmt::pct(floor)
    );
    anyhow::ensure!(out.evals <= evals, "spent {} evals over the budget {evals}", out.evals);

    let provenance = if compensate_on {
        format!("mcts:{seed}/{evals}+comp")
    } else {
        format!("mcts:{seed}/{evals}")
    };
    let plan_json = best_plan.to_json_with(&ctx.model, Some(&provenance));
    // Round-trip check: the saved artifact must reload into the very same
    // plan (compensation terms included) and score identically on the
    // emulator.
    let reloaded = ExecutionPlan::from_json(&plan_json, &ctx.model)?;
    anyhow::ensure!(reloaded == best_plan, "plan JSON did not round-trip");
    let re_acc = ctx.eval_plan(reloaded)?;
    anyhow::ensure!(
        re_acc == out.accuracy || out.retrained > 0,
        "reloaded plan scored {} vs searched {}",
        fmt::pct(re_acc),
        fmt::pct(out.accuracy)
    );
    if let Some(path) = args.get("out") {
        std::fs::write(path, &plan_json).with_context(|| format!("writing {path}"))?;
        say(format!("plan written to {path} (provenance {provenance})"));
    }

    // --compensate acceptance check: re-run the identical pipeline without
    // the correction table and demand the compensated search bought a
    // strictly cheaper plan at the same floor — even after charging the
    // compensation adds in the comp-aware cost model.
    let mut comp_vs_plain: Option<(f64, f64)> = None;
    if let Some(table) = &comp_table {
        let macs = adapt::search::layer_macs(&ctx.model);
        let outs = adapt::search::layer_outputs(&ctx.model);
        let plain_ctx = mk_ctx(None);
        let pairs0 = sweep_pairs(&plain_ctx, &ref_plan, &layers, &acus, pool.as_ref())?;
        let worst0 = worst_drops(base_acc, &pairs0, layers.len(), acus.len());
        let (gplan0, gacc0, _) = greedy_mixed(
            &plain_ctx, &ref_plan, &reference, base_acc, &layers, &worst0, &acus, budget,
        )?;
        let space0 = mcts::SearchSpace::build(
            &plain_ctx.model,
            ref_plan.clone(),
            &reference,
            base_acc,
            budget,
            &layers,
            &pairs0,
            &acus,
        )?;
        let out0 =
            mcts::search(&plain_ctx, space0, &mcfg, Some((&gplan0, gacc0)), pool.as_ref(), None)?;
        let plain_cost = adapt::search::plan_cost_macs(&macs, &out0.plan);
        let mut winner = out.plan.clone();
        adapt::compensate::apply_table(table, &mut winner);
        let comp_cost = adapt::search::plan_cost_comp(&macs, &outs, &winner);
        say(format!(
            "compensated search: comp-aware cost {comp_cost:.4} vs best uncompensated \
             {plain_cost:.4} (accuracy {} vs {})",
            fmt::pct(out.accuracy),
            fmt::pct(out0.accuracy),
        ));
        anyhow::ensure!(
            comp_cost < plain_cost,
            "--compensate did not buy a cheaper plan: {comp_cost:.4} >= {plain_cost:.4}"
        );
        comp_vs_plain = Some((comp_cost, plain_cost));
    }
    say(format!("search done in {}", fmt::dur(wall)));

    if json_mode {
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("method".to_string(), Json::Str("mcts".into()));
        doc.insert("seed".to_string(), Json::Num(seed as f64));
        doc.insert("eval_budget".to_string(), Json::Num(evals as f64));
        doc.insert("base_accuracy".to_string(), Json::Num(base_acc));
        doc.insert("floor".to_string(), Json::Num(floor));
        doc.insert("reference".to_string(), Json::Str(reference));
        doc.insert(
            "acus".to_string(),
            Json::Arr(acus.iter().map(|a| Json::Str(a.clone())).collect()),
        );
        doc.insert("sweep_pairs".to_string(), Json::Num(pair_accs.len() as f64));
        let mut g = std::collections::BTreeMap::new();
        g.insert("accuracy".to_string(), Json::Num(gacc));
        g.insert("evals".to_string(), Json::Num(gevals as f64));
        g.insert("savings".to_string(), Json::Num(greedy_savings));
        doc.insert("greedy".to_string(), Json::Obj(g));
        let mut m = std::collections::BTreeMap::new();
        m.insert("accuracy".to_string(), Json::Num(out.accuracy));
        m.insert("cost".to_string(), Json::Num(out.cost));
        m.insert("savings".to_string(), Json::Num(out.savings));
        m.insert("reward".to_string(), Json::Num(out.reward));
        m.insert("evals".to_string(), Json::Num(out.evals as f64));
        m.insert("cache_hits".to_string(), Json::Num(out.cache_hits as f64));
        m.insert("playouts".to_string(), Json::Num(out.playouts as f64));
        m.insert("retrained".to_string(), Json::Num(out.retrained as f64));
        m.insert("feasible".to_string(), Json::Bool(out.feasible));
        doc.insert("mcts".to_string(), Json::Obj(m));
        doc.insert("accuracy".to_string(), Json::Num(out.accuracy));
        doc.insert("mcts_not_worse".to_string(), Json::Bool(out.reward >= greedy_reward));
        doc.insert("reload_ok".to_string(), Json::Bool(true));
        doc.insert("compensate".to_string(), Json::Bool(compensate_on));
        if let Some((comp_cost, plain_cost)) = comp_vs_plain {
            doc.insert("comp_cost".to_string(), Json::Num(comp_cost));
            doc.insert("plain_cost".to_string(), Json::Num(plain_cost));
            doc.insert(
                "compensated_layers".to_string(),
                Json::Num(best_plan.compensation.len() as f64),
            );
        }
        doc.insert("provenance".to_string(), Json::Str(provenance));
        doc.insert("wall_s".to_string(), Json::Num(wall.as_secs_f64()));
        println!("{}", Json::Obj(doc).to_string());
    }
    Ok(())
}

/// Create a plan version on a registry model; returns its number.
fn create_candidate(addr: &str, model: &str, body: &str) -> Result<u64> {
    let (status, resp) = client::http_call(
        addr,
        "POST",
        &format!("/v2/models/{model}/plans"),
        Some(body),
    )?;
    if status != 200 {
        bail!("creating plan version failed ({status}): {resp}");
    }
    Ok(Json::parse(&resp)?.get("version")?.i64()? as u64)
}

/// Cross-check: Rust emulator (both styles) vs the XLA approx artifact on
/// one batch — the end-to-end numeric agreement test, runnable anywhere.
fn selftest(rt: &mut Runtime, name: &str) -> Result<()> {
    let sizes = Sizes::small();
    let model = rt.manifest.model(name)?.clone();
    let ds = adapt::data::load(&model.dataset, &sizes);
    let mut st = experiments::ensure_pretrained(rt, name, &sizes, 0.1, false)?;
    ops::calibrate(&mut *rt, &mut st, &ds, 1, CalibratorKind::Percentile, 0.999)?;
    let lut_lit = ops::load_lut_lit(rt, "mul8s_1l2h_like")?;
    let bs = rt.manifest.batch;

    let x = ops::batch_input(&model, &ds.eval, 0, bs)?;
    let xla_out = ops::infer_batch(rt, &st, InferVariant::ApproxLut, &x, Some(&lut_lit))?;

    let plan = retransform(&model, &Policy::all(LayerMode::lut("mul8s_1l2h_like")));
    let luts = LutRegistry::from_manifest(&rt.manifest);
    let params = st.params_tensors()?;
    let scales = st.act_scales.clone().unwrap();
    let input = if model.input_dtype == "i32" {
        Value::I(ds.eval.batch_tensor_i(0, bs))
    } else {
        Value::F(ds.eval.batch_tensor(0, bs))
    };
    for style in [Style::Naive, Style::Optimized { threads: 2 }] {
        let exec = Executor::new(
            &model,
            params.clone(),
            plan.clone(),
            scales.clone(),
            &luts,
            style,
        )?;
        let out = exec.forward(input.clone())?;
        anyhow::ensure!(out.data.len() == xla_out.len(), "output size mismatch");
        let mut max_err = 0f32;
        let mut big = 0usize;
        for (a, b) in out.data.iter().zip(&xla_out) {
            let e = (a - b).abs();
            max_err = max_err.max(e);
            if e > 1e-3 {
                big += 1;
            }
        }
        // The integer GEMMs are bit-exact; residual differences stem from
        // ulp-level float divergence (pooling sums, dequant) flipping a
        // rounding boundary in a downstream quantizer — one early flip
        // shifts many outputs by ~one quant step. So the check is
        // behavioral: per-sample argmax agreement (classifiers) plus a
        // loose magnitude bound; a layout/logic bug fails both instantly.
        let rows = model.out_dim.max(1);
        let nsamples = out.data.len() / rows;
        let mut argmax_agree = 0usize;
        for s in 0..nsamples {
            let a = &out.data[s * rows..(s + 1) * rows];
            let b = &xla_out[s * rows..(s + 1) * rows];
            let am = (0..rows).max_by(|&i, &j| a[i].total_cmp(&a[j])).unwrap();
            let bm = (0..rows).max_by(|&i, &j| b[i].total_cmp(&b[j])).unwrap();
            if am == bm {
                argmax_agree += 1;
            }
        }
        println!(
            "selftest {name} {style:?}: max |rust - xla| = {max_err:.3e}, {big}/{} > 1e-3, argmax agree {argmax_agree}/{nsamples}",
            out.data.len()
        );
        anyhow::ensure!(max_err < 0.2, "emulator/XLA disagreement: {max_err}");
        anyhow::ensure!(
            argmax_agree * 100 >= nsamples * 95,
            "behavioral disagreement: {argmax_agree}/{nsamples}"
        );
    }
    println!("selftest {name}: OK");
    Ok(())
}
