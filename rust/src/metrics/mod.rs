//! Evaluation metrics (Table 2's accuracy columns).

/// Top-1 accuracy over (N, C) logits.
pub fn top1(logits: &[f32], classes: usize, labels: &[i32]) -> f64 {
    let n = labels.len();
    assert_eq!(logits.len(), n * classes);
    let mut hits = 0usize;
    for (i, &l) in labels.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best == l as usize {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

/// Top-5 accuracy (the paper reports SqueezeNet at top-5).
pub fn top5(logits: &[f32], classes: usize, labels: &[i32]) -> f64 {
    let n = labels.len();
    assert_eq!(logits.len(), n * classes);
    let k = 5.min(classes);
    let mut hits = 0usize;
    for (i, &l) in labels.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let mut idx: Vec<usize> = (0..classes).collect();
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
        if idx[..k].contains(&(l as usize)) {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

/// Pixel accuracy for reconstruction: fraction of pixels whose binarized
/// (>= 0.5) reconstruction matches the binarized target — the "accuracy"
/// convention behind the paper's 99.9x% VAE numbers.
pub fn pixel_accuracy(recon: &[f32], target: &[f32]) -> f64 {
    assert_eq!(recon.len(), target.len());
    let hits = recon
        .iter()
        .zip(target)
        .filter(|(r, t)| (**r >= 0.5) == (**t >= 0.5))
        .count();
    hits as f64 / recon.len() as f64
}

/// Metric dispatch by manifest name.
pub fn compute(metric: &str, out: &[f32], out_dim: usize, labels: &[i32], target: &[f32]) -> f64 {
    match metric {
        "top1" => top1(out, out_dim, labels),
        "top5" => top5(out, out_dim, labels),
        "pixel" => pixel_accuracy(out, target),
        _ => f64::NAN,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_counts_argmax() {
        let logits = [0.1, 0.9, 0.8, 0.2];
        assert_eq!(top1(&logits, 2, &[1, 0]), 1.0);
        assert_eq!(top1(&logits, 2, &[0, 1]), 0.0);
    }

    #[test]
    fn top5_is_lenient() {
        // 6 classes, correct label ranked 5th -> top5 hit, top1 miss.
        let logits = [0.9, 0.8, 0.7, 0.6, 0.5, 0.4];
        assert_eq!(top1(&logits, 6, &[4]), 0.0);
        assert_eq!(top5(&logits, 6, &[4]), 1.0);
        assert_eq!(top5(&logits, 6, &[5]), 0.0);
    }

    #[test]
    fn pixel_accuracy_binarizes() {
        let recon = [0.6, 0.4, 0.9, 0.1];
        let target = [1.0, 0.0, 0.0, 0.0];
        assert_eq!(pixel_accuracy(&recon, &target), 0.75);
    }
}
