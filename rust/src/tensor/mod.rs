//! Minimal dense tensors (f32 / i32) + the conv-to-GEMM reshape (Fig. 3).
//!
//! Row-major, NHWC layout for images. Deliberately small: the Rust
//! emulators need exactly shaped storage, im2col, and a handful of
//! elementwise ops — everything heavier runs through the GEMM engines in
//! [`crate::emulator`] or through XLA via [`crate::runtime`].

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Dense row-major i32 tensor (quantized activations / LUT indices).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; numel(shape)],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        if numel(shape) != data.len() {
            bail!("shape {:?} != data len {}", shape, data.len());
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        if numel(shape) != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Leading dimension (batch).
    pub fn dim0(&self) -> usize {
        self.shape.first().copied().unwrap_or(1)
    }

    /// Max |x| over the whole tensor (per-tensor calibration "max" method).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    /// Elementwise add (same shape).
    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("add shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Ok(Tensor {
            shape: self.shape.clone(),
            data,
        })
    }

    /// Concatenate along the last axis (channel concat for fire/dense/
    /// inception blocks).
    pub fn concat_last(parts: &[&Tensor]) -> Result<Tensor> {
        let first = parts.first().copied().expect("concat of nothing");
        let lead = &first.shape[..first.shape.len() - 1];
        let mut c_total = 0;
        for p in parts {
            if &p.shape[..p.shape.len() - 1] != lead {
                bail!("concat leading dims differ");
            }
            c_total += *p.shape.last().unwrap();
        }
        let rows: usize = lead.iter().product();
        let mut shape = lead.to_vec();
        shape.push(c_total);
        let mut data = Vec::with_capacity(rows * c_total);
        for r in 0..rows {
            for p in parts {
                let c = *p.shape.last().unwrap();
                data.extend_from_slice(&p.data[r * c..(r + 1) * c]);
            }
        }
        Ok(Tensor { shape, data })
    }

    /// Slice the last axis [start, end).
    pub fn slice_last(&self, start: usize, end: usize) -> Tensor {
        let c = *self.shape.last().unwrap();
        assert!(start < end && end <= c);
        let rows = self.data.len() / c;
        let w = end - start;
        let mut data = Vec::with_capacity(rows * w);
        for r in 0..rows {
            data.extend_from_slice(&self.data[r * c + start..r * c + end]);
        }
        let mut shape = self.shape.clone();
        *shape.last_mut().unwrap() = w;
        Tensor { shape, data }
    }
}

impl TensorI32 {
    /// Slice the last axis [start, end) (grouped-conv channel split).
    pub fn slice_last(&self, start: usize, end: usize) -> TensorI32 {
        let c = *self.shape.last().unwrap();
        assert!(start < end && end <= c);
        let rows = self.data.len() / c;
        let w = end - start;
        let mut data = Vec::with_capacity(rows * w);
        for r in 0..rows {
            data.extend_from_slice(&self.data[r * c + start..r * c + end]);
        }
        let mut shape = self.shape.clone();
        *shape.last_mut().unwrap() = w;
        TensorI32 { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> TensorI32 {
        TensorI32 {
            shape: shape.to_vec(),
            data: vec![0; numel(shape)],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<TensorI32> {
        if numel(shape) != data.len() {
            bail!("shape {:?} != data len {}", shape, data.len());
        }
        Ok(TensorI32 {
            shape: shape.to_vec(),
            data,
        })
    }
}

/// Output spatial size of a convolution dimension.
pub fn conv_out(size: usize, k: usize, stride: usize, pad: usize) -> usize {
    (size + 2 * pad - k) / stride + 1
}

/// im2col over quantized NHWC activations: (N,H,W,C) i32 -> patch matrix
/// (N*Ho*Wo, kh*kw*C) with feature order **(dy, dx, c)** — identical to
/// `python/compile/nn.py::im2col`, so the GEMM below reproduces conv2d
/// given the weight tensor flattened (kh, kw, cin, cout) -> (kh*kw*cin, cout).
///
/// Out-of-image taps contribute 0, which every ACU maps to a 0 product, so
/// zero padding is exact (same argument as the Pallas kernel's padding).
pub fn im2col_i32(
    x: &TensorI32,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> TensorI32 {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = conv_out(h, kh, stride, pad);
    let wo = conv_out(w, kw, stride, pad);
    let kf = kh * kw * c;
    let mut out = vec![0i32; n * ho * wo * kf];
    im2col_i32_range_into(&x.data, &x.shape, kh, kw, stride, pad, 0, c, &mut out);
    TensorI32 {
        shape: vec![n * ho * wo, kf],
        data: out,
    }
}

/// Allocation-free im2col over a channel range `[c0, c1)` of a quantized
/// NHWC tensor (given as raw data + shape so scratch buffers qualify),
/// writing the `(N*Ho*Wo, kh*kw*(c1-c0))` patch matrix into a
/// caller-provided buffer (the executor's scratch arena). The channel
/// range *is* grouped convolution's input split, so groups never need a
/// sliced copy of the activation tensor.
#[allow(clippy::too_many_arguments)]
pub fn im2col_i32_range_into(
    x: &[i32],
    shape: &[usize],
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    c0: usize,
    c1: usize,
    out: &mut [i32],
) {
    let (n, h, w, ct) = (shape[0], shape[1], shape[2], shape[3]);
    assert!(c0 < c1 && c1 <= ct);
    let ho = conv_out(h, kh, stride, pad);
    let wo = conv_out(w, kw, stride, pad);
    let c = c1 - c0;
    let kf = kh * kw * c;
    assert_eq!(out.len(), n * ho * wo * kf);
    // Scratch buffers are reused across layers: stale values must become
    // the zero padding the kernels rely on.
    out.fill(0);
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((ni * ho + oy) * wo + ox) * kf;
                for dy in 0..kh {
                    let iy = (oy * stride + dy) as isize - pad as isize;
                    for dx in 0..kw {
                        let ix = (ox * stride + dx) as isize - pad as isize;
                        let dst = row + (dy * kw + dx) * c;
                        if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                            let src = ((ni * h + iy as usize) * w + ix as usize) * ct + c0;
                            out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                        }
                        // else: zeros already in place
                    }
                }
            }
        }
    }
}

/// f32 variant used by the fp32 reference path of the Rust emulator.
pub fn im2col_f32(x: &Tensor, kh: usize, kw: usize, stride: usize, pad: usize) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let ho = conv_out(h, kh, stride, pad);
    let wo = conv_out(w, kw, stride, pad);
    let kf = kh * kw * c;
    let mut out = vec![0f32; n * ho * wo * kf];
    im2col_f32_range_into(&x.data, &x.shape, kh, kw, stride, pad, 0, c, &mut out);
    Tensor {
        shape: vec![n * ho * wo, kf],
        data: out,
    }
}

/// f32 twin of [`im2col_i32_range_into`].
#[allow(clippy::too_many_arguments)]
pub fn im2col_f32_range_into(
    x: &[f32],
    shape: &[usize],
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    c0: usize,
    c1: usize,
    out: &mut [f32],
) {
    let (n, h, w, ct) = (shape[0], shape[1], shape[2], shape[3]);
    assert!(c0 < c1 && c1 <= ct);
    let ho = conv_out(h, kh, stride, pad);
    let wo = conv_out(w, kw, stride, pad);
    let c = c1 - c0;
    let kf = kh * kw * c;
    assert_eq!(out.len(), n * ho * wo * kf);
    out.fill(0.0);
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((ni * ho + oy) * wo + ox) * kf;
                for dy in 0..kh {
                    let iy = (oy * stride + dy) as isize - pad as isize;
                    for dx in 0..kw {
                        let ix = (ox * stride + dx) as isize - pad as isize;
                        let dst = row + (dy * kw + dx) * c;
                        if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                            let src = ((ni * h + iy as usize) * w + ix as usize) * ct + c0;
                            out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                        }
                    }
                }
            }
        }
    }
}

/// Slice channels [c0, c1) of an NHWC tensor (grouped convolution).
pub fn channel_slice(x: &Tensor, c0: usize, c1: usize) -> Tensor {
    x.slice_last(c0, c1)
}

/// Adjoint of [`im2col_f32_range_into`]: scatter-**add** a patch-matrix
/// gradient `(N*Ho*Wo, kh*kw*(c1-c0))` back onto the NHWC input gradient
/// buffer over channels `[c0, c1)` (the conv-backward `dX` accumulation).
/// Taps that fell on zero padding in the forward are dropped. Unlike the
/// forward variant this *adds* into `out`, so grouped convolutions can
/// scatter each group's patches into the same gradient buffer.
#[allow(clippy::too_many_arguments)]
pub fn col2im_f32_range_add(
    patches: &[f32],
    shape: &[usize],
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    c0: usize,
    c1: usize,
    out: &mut [f32],
) {
    let (n, h, w, ct) = (shape[0], shape[1], shape[2], shape[3]);
    assert!(c0 < c1 && c1 <= ct);
    let ho = conv_out(h, kh, stride, pad);
    let wo = conv_out(w, kw, stride, pad);
    let c = c1 - c0;
    let kf = kh * kw * c;
    assert_eq!(patches.len(), n * ho * wo * kf);
    assert_eq!(out.len(), n * h * w * ct);
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((ni * ho + oy) * wo + ox) * kf;
                for dy in 0..kh {
                    let iy = (oy * stride + dy) as isize - pad as isize;
                    for dx in 0..kw {
                        let ix = (ox * stride + dx) as isize - pad as isize;
                        let src = row + (dy * kw + dx) * c;
                        if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                            let dst = ((ni * h + iy as usize) * w + ix as usize) * ct + c0;
                            for (o, &p) in out[dst..dst + c]
                                .iter_mut()
                                .zip(&patches[src..src + c])
                            {
                                *o += p;
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_out_sizes() {
        assert_eq!(conv_out(32, 3, 1, 1), 32);
        assert_eq!(conv_out(32, 3, 2, 1), 16);
        assert_eq!(conv_out(28, 1, 1, 0), 28);
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: patches == flattened input.
        let x = TensorI32::from_vec(&[1, 2, 2, 3], (0..12).collect()).unwrap();
        let p = im2col_i32(&x, 1, 1, 1, 0);
        assert_eq!(p.shape, vec![4, 3]);
        assert_eq!(p.data, (0..12).collect::<Vec<i32>>());
    }

    #[test]
    fn im2col_3x3_center_of_padded() {
        // Single pixel 1 at center of 3x3 image; 3x3 kernel pad 1:
        // the center output row sees the pixel at patch position (1,1).
        let mut data = vec![0i32; 9];
        data[4] = 7; // (y=1, x=1)
        let x = TensorI32::from_vec(&[1, 3, 3, 1], data).unwrap();
        let p = im2col_i32(&x, 3, 3, 1, 1);
        assert_eq!(p.shape, vec![9, 9]);
        // output row 4 (center) has the pixel at feature index dy=1,dx=1 -> 4
        assert_eq!(p.data[4 * 9 + 4], 7);
        // output row 0 (top-left) sees it at dy=2,dx=2 -> 8
        assert_eq!(p.data[8], 7);
    }

    #[test]
    fn im2col_feature_order_is_dy_dx_c() {
        // 2 channels, 2x2 kernel: feature layout must be
        // [(0,0,c0),(0,0,c1),(0,1,c0),(0,1,c1),(1,0,c0),...]
        let x = TensorI32::from_vec(&[1, 2, 2, 2], vec![10, 11, 20, 21, 30, 31, 40, 41])
            .unwrap();
        let p = im2col_i32(&x, 2, 2, 1, 0);
        assert_eq!(p.shape, vec![1, 8]);
        assert_eq!(p.data, vec![10, 11, 20, 21, 30, 31, 40, 41]);
    }

    #[test]
    fn concat_and_slice_roundtrip() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::from_vec(&[2, 3], vec![5., 6., 7., 8., 9., 10.]).unwrap();
        let c = Tensor::concat_last(&[&a, &b]).unwrap();
        assert_eq!(c.shape, vec![2, 5]);
        assert_eq!(c.data, vec![1., 2., 5., 6., 7., 3., 4., 8., 9., 10.]);
        assert_eq!(c.slice_last(0, 2).data, a.data);
        assert_eq!(c.slice_last(2, 5).data, b.data);
    }

    #[test]
    fn stride_two() {
        let x = TensorI32::from_vec(&[1, 4, 4, 1], (0..16).collect()).unwrap();
        let p = im2col_i32(&x, 2, 2, 2, 0);
        assert_eq!(p.shape, vec![4, 4]);
        // windows at (0,0), (0,2), (2,0), (2,2)
        assert_eq!(&p.data[0..4], &[0, 1, 4, 5]);
        assert_eq!(&p.data[4..8], &[2, 3, 6, 7]);
        assert_eq!(&p.data[8..12], &[8, 9, 12, 13]);
        assert_eq!(&p.data[12..16], &[10, 11, 14, 15]);
    }

    #[test]
    fn im2col_range_matches_slice_then_im2col() {
        // Channel-range im2col must equal slicing the channels first —
        // the grouped-conv equivalence the executor's scratch path uses.
        let x = TensorI32::from_vec(&[2, 3, 3, 4], (0..72).collect()).unwrap();
        for (c0, c1) in [(0, 2), (2, 4), (1, 3), (0, 4)] {
            let sliced = im2col_i32(&x.slice_last(c0, c1), 2, 2, 1, 1);
            let mut out = vec![7i32; sliced.data.len()]; // stale garbage
            im2col_i32_range_into(&x.data, &x.shape, 2, 2, 1, 1, c0, c1, &mut out);
            assert_eq!(out, sliced.data, "range {c0}..{c1}");
        }
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // PROPERTY: <im2col(x), y> == <x, col2im(y)> for every channel
        // range — the defining identity of the conv-backward scatter.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        let shape = [2usize, 5, 4, 3];
        let x: Vec<f32> = (0..shape.iter().product::<usize>())
            .map(|_| rng.next_gauss())
            .collect();
        for (c0, c1, kh, kw, stride, pad) in
            [(0, 3, 3, 3, 1, 1), (1, 3, 2, 2, 2, 0), (0, 2, 3, 2, 1, 1)]
        {
            let c = c1 - c0;
            let ho = conv_out(shape[1], kh, stride, pad);
            let wo = conv_out(shape[2], kw, stride, pad);
            let np = shape[0] * ho * wo * kh * kw * c;
            let mut patches = vec![0f32; np];
            im2col_f32_range_into(&x, &shape, kh, kw, stride, pad, c0, c1, &mut patches);
            let y: Vec<f32> = (0..np).map(|_| rng.next_gauss()).collect();
            let mut back = vec![0f32; x.len()];
            col2im_f32_range_add(&y, &shape, kh, kw, stride, pad, c0, c1, &mut back);
            let lhs: f64 = patches
                .iter()
                .zip(&y)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            let rhs: f64 = x
                .iter()
                .zip(&back)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            assert!(
                (lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()),
                "adjoint mismatch: {lhs} vs {rhs} ({c0}..{c1} k{kh}x{kw} s{stride} p{pad})"
            );
        }
    }

    #[test]
    fn abs_max() {
        let t = Tensor::from_vec(&[3], vec![-2.5, 1.0, 2.0]).unwrap();
        assert_eq!(t.abs_max(), 2.5);
    }
}
